//! The plan service's wire surface: a line-oriented request loop (one
//! request per line in, one JSON document per line out) suitable for
//! scripting, piping, and tests — `osdp serve` binds it to
//! stdin/stdout, `osdp query` runs a single request through the same
//! code path.
//!
//! ```text
//! query setting=48L/1024H mem=8 batch=4 [devices=8] [cluster=PRESET]
//!       [g=0,4] [engine=frontier|bb] [threads=N] [ckpt] [fine]
//!       [no-scopes] [no-warm]
//! sweep setting=48L/1024H mem=8 [batch-cap=64] [...same knobs]
//! replan setting=... mem=... {batch=N | batch-cap=N} [...same knobs]
//!        [new-devices=M] [new-cluster=PRESET] [new-mem=G]
//!        [sweep-clusters]
//! stats
//! metrics
//! trace [ID]
//! quit
//! shutdown
//! ```
//!
//! `quit` ends one connection (or the stdin loop); `shutdown` asks the
//! whole socket front-end ([`super::frontend`]) to stop accepting and
//! drain — on the stdin loop the two are equivalent. The same grammar is
//! also the cache's *warm-up* format: every cached plan stores its
//! canonical request line ([`request_line`]), so an epoch bump can
//! re-plan yesterday's hottest queries before serving today's traffic.
//!
//! Settings are zoo names (`48L/1024H`) or custom
//! `gpt:vocab,seq,layers,hidden,heads` specs. Malformed requests answer
//! `{"ok":false,"error":"bad-request",...}` — the loop never panics and
//! never exits on bad input (error-path property tests in
//! `rust/tests/plan_service.rs`).

use super::telemetry::{ObservedShape, Telemetry};
use super::{Answer, PlanError, PlanQuery, PlanService, QueryResponse,
            QueryShape};
use crate::planner::Engine;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::io::{BufRead, Write};
use std::time::Instant;

/// One parsed protocol line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Query(PlanQuery),
    /// Elastic replan: the old query (whose cluster just changed) plus
    /// the cluster it changed *to*; `sweep_clusters` swaps the single
    /// replan for a capacity sweep down the device-count ladder.
    Replan {
        query: PlanQuery,
        new_cluster: super::ClusterSpec,
        sweep_clusters: bool,
    },
    Stats,
    /// Prometheus text-format snapshot of every counter, gauge, and
    /// histogram the service keeps (the same numbers `stats` reports
    /// as JSON — the exposition test pins that equality).
    Metrics,
    /// `trace` lists the completed-trace ring; `trace ID` returns one
    /// trace's full span tree and convergence timeline.
    Trace(Option<String>),
    Quit,
    Shutdown,
}

/// What the transport should do after answering a line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LineOutcome {
    /// Keep reading from this connection.
    Continue,
    /// Close this connection; the service keeps running.
    Quit,
    /// Drain and stop the whole front-end.
    Shutdown,
}

/// Parse a protocol line. Strict: unknown keys are rejected so typos
/// fail loudly instead of planning the wrong thing.
pub fn parse_request(line: &str) -> Result<Request, PlanError> {
    let mut toks = line.split_whitespace();
    let verb = toks
        .next()
        .ok_or_else(|| PlanError::BadRequest("empty request".into()))?;
    match verb {
        "stats" => Ok(Request::Stats),
        "metrics" => Ok(Request::Metrics),
        "trace" => {
            let id = toks.next().map(str::to_string);
            if toks.next().is_some() {
                return Err(PlanError::BadRequest(
                    "trace takes at most one argument (a trace id)"
                        .into(),
                ));
            }
            Ok(Request::Trace(id))
        }
        "quit" | "exit" => Ok(Request::Quit),
        "shutdown" => Ok(Request::Shutdown),
        "query" | "sweep" | "replan" => parse_query(verb, toks),
        other => Err(PlanError::BadRequest(format!(
            "unknown verb '{other}' (query | sweep | replan | stats | \
             metrics | trace | quit | shutdown)"
        ))),
    }
}

fn parse_query<'a>(verb: &str, toks: impl Iterator<Item = &'a str>)
                   -> Result<Request, PlanError> {
    let bad = PlanError::BadRequest;
    let mut q = PlanQuery::batch("", 8.0, 1);
    let mut setting = None;
    let mut batch = None;
    let mut batch_cap = None;
    // replan-only: the cluster the hardware changed to
    let mut new_devices = None;
    let mut new_mem = None;
    let mut new_preset: Option<String> = None;
    let mut sweep_clusters = false;
    for tok in toks {
        match tok.split_once('=') {
            Some(("setting", v)) => setting = Some(v.to_string()),
            Some(("mem", v)) => {
                q.cluster.mem_gib = v
                    .parse()
                    .map_err(|_| bad(format!("mem: bad number '{v}'")))?;
            }
            Some(("devices", v)) => {
                q.cluster.devices = Some(parse_usize("devices", v)?);
            }
            Some(("cluster", v)) => q.cluster.preset = v.to_string(),
            Some(("g", v)) => {
                q.search.granularities = v
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(|s| parse_usize("g", s.trim()))
                    .collect::<Result<_, _>>()?;
            }
            Some(("engine", v)) => {
                q.engine = Engine::parse(v).ok_or_else(|| {
                    bad(format!("engine: want frontier|bb, got '{v}'"))
                })?;
            }
            Some(("threads", v)) => q.threads = parse_usize("threads", v)?,
            Some(("batch", v)) if verb != "sweep" => {
                batch = Some(parse_usize("batch", v)?);
            }
            Some(("batch-cap", v)) if verb != "query" => {
                batch_cap = Some(parse_usize("batch-cap", v)?);
            }
            Some(("new-devices", v)) if verb == "replan" => {
                new_devices = Some(parse_usize("new-devices", v)?);
            }
            Some(("new-mem", v)) if verb == "replan" => {
                new_mem = Some(v.parse::<f64>().map_err(|_| {
                    bad(format!("new-mem: bad number '{v}'"))
                })?);
            }
            Some(("new-cluster", v)) if verb == "replan" => {
                new_preset = Some(v.to_string());
            }
            None if tok == "ckpt" => q.search.checkpointing = true,
            None if tok == "fine" => q.search.paper_granularity = false,
            None if tok == "no-scopes" => q.search.hybrid_scopes = false,
            None if tok == "no-warm" => q.warm = false,
            None if tok == "sweep-clusters" && verb == "replan" => {
                sweep_clusters = true;
            }
            _ => {
                return Err(bad(format!(
                    "unexpected parameter '{tok}' for '{verb}'"
                )));
            }
        }
    }
    q.setting = setting
        .ok_or_else(|| bad("missing required setting=...".to_string()))?;
    // the shape is the single source of truth for the sweep cap
    // (SearchConfig::max_batch is unread on the service path)
    q.shape = match verb {
        "query" => QueryShape::Batch(
            batch.ok_or_else(|| bad("query needs batch=N".to_string()))?,
        ),
        "replan" => match (batch, batch_cap) {
            (Some(b), None) => QueryShape::Batch(b),
            (None, Some(cap)) => QueryShape::Sweep { max_batch: cap },
            (Some(_), Some(_)) => {
                return Err(bad("replan takes batch=N or batch-cap=N, \
                                not both"
                    .to_string()));
            }
            (None, None) => {
                return Err(bad(
                    "replan needs batch=N or batch-cap=N".to_string()
                ));
            }
        },
        _ => QueryShape::Sweep { max_batch: batch_cap.unwrap_or(64) },
    };
    if verb != "replan" {
        return Ok(Request::Query(q));
    }
    if new_devices.is_none() && new_mem.is_none() && new_preset.is_none()
        && !sweep_clusters
    {
        return Err(bad("replan needs at least one of new-devices= / \
                        new-cluster= / new-mem= / sweep-clusters"
            .to_string()));
    }
    let new_cluster = super::ClusterSpec {
        preset: new_preset
            .clone()
            .unwrap_or_else(|| q.cluster.preset.clone()),
        devices: match (new_devices, &new_preset) {
            (Some(d), _) => Some(d),
            // a preset change invalidates the old device count (the
            // new preset may not be size-parametric); it must be
            // restated explicitly via new-devices
            (None, Some(_)) => None,
            (None, None) => q.cluster.devices,
        },
        mem_gib: new_mem.unwrap_or(q.cluster.mem_gib),
    };
    Ok(Request::Replan { query: q, new_cluster, sweep_clusters })
}

fn parse_usize(key: &str, v: &str) -> Result<usize, PlanError> {
    v.parse().map_err(|_| {
        PlanError::BadRequest(format!("{key}: bad integer '{v}'"))
    })
}

/// Canonical protocol line for a query — the inverse of
/// [`parse_request`]: any query the grammar can express round-trips,
/// `parse_request(&request_line(q)?) == Ok(Request::Query(q))` (pinned
/// in tests). Cache entries store this line so the epoch-bump warm-up
/// can replay yesterday's traffic through the ordinary request path.
///
/// `None` when the query is not expressible on one whitespace-split
/// line (a setting containing whitespace — impossible to create *via*
/// the protocol, possible via the API). `Engine::UnfoldedBb` serializes
/// as `bb`: engines are perf knobs outside the cache key, and every
/// engine returns the bit-identical optimum, so the replay is
/// answer-preserving.
pub fn request_line(q: &PlanQuery) -> Option<String> {
    if q.setting.is_empty() || q.setting.chars().any(|c| c.is_whitespace())
    {
        return None;
    }
    let mut s = String::new();
    match q.shape {
        QueryShape::Batch(b) => {
            s.push_str(&format!("query setting={} mem={} batch={b}",
                                q.setting, q.cluster.mem_gib));
        }
        QueryShape::Sweep { max_batch } => {
            s.push_str(&format!("sweep setting={} mem={} batch-cap={}",
                                q.setting, q.cluster.mem_gib, max_batch));
        }
    }
    if let Some(d) = q.cluster.devices {
        s.push_str(&format!(" devices={d}"));
    }
    if q.cluster.preset != "rtx_titan" {
        s.push_str(&format!(" cluster={}", q.cluster.preset));
    }
    let g: Vec<String> =
        q.search.granularities.iter().map(|g| g.to_string()).collect();
    s.push_str(&format!(" g={}", g.join(",")));
    if q.engine != Engine::Frontier {
        s.push_str(" engine=bb");
    }
    if q.threads != 0 {
        s.push_str(&format!(" threads={}", q.threads));
    }
    if q.search.checkpointing {
        s.push_str(" ckpt");
    }
    if !q.search.paper_granularity {
        s.push_str(" fine");
    }
    if !q.search.hybrid_scopes {
        s.push_str(" no-scopes");
    }
    if !q.warm {
        s.push_str(" no-warm");
    }
    Some(s)
}

/// Render a query outcome as the single-line JSON the protocol speaks.
pub fn render_response(outcome: &Result<QueryResponse, PlanError>)
                       -> String {
    let mut o = BTreeMap::new();
    match outcome {
        Err(e) => {
            o.insert("ok".into(), Json::Bool(false));
            o.insert("error".into(), Json::Str(e.kind().into()));
            o.insert("detail".into(), Json::Str(e.to_string()));
        }
        Ok(resp) => {
            o.insert("ok".into(), Json::Bool(true));
            o.insert("source".into(),
                     Json::Str(resp.source.label().into()));
            o.insert("key".into(), Json::Str(resp.key.id()));
            if let Some(id) = &resp.trace_id {
                o.insert("trace_id".into(), Json::Str(id.clone()));
            }
            match &resp.answer {
                Answer::Plan { plan, stats } => {
                    o.insert("kind".into(), Json::Str("plan".into()));
                    o.insert("batch".into(),
                             Json::Num(plan.batch as f64));
                    o.insert("time_s".into(), Json::Num(plan.cost.time));
                    o.insert("peak_bytes".into(),
                             Json::Num(plan.cost.peak_mem));
                    o.insert(
                        "throughput".into(),
                        Json::Num(plan.throughput(resp.n_devices)),
                    );
                    o.insert("nodes".into(),
                             Json::Num(stats.nodes as f64));
                    o.insert("complete".into(),
                             Json::Bool(stats.complete));
                    o.insert(
                        "choice".into(),
                        Json::Arr(plan.choice.iter()
                                      .map(|&c| Json::Num(c as f64))
                                      .collect()),
                    );
                }
                Answer::Sweep { plans, best, stats } => {
                    let winner = &plans[*best];
                    o.insert("kind".into(), Json::Str("sweep".into()));
                    o.insert("best_batch".into(),
                             Json::Num(winner.batch as f64));
                    o.insert(
                        "throughput".into(),
                        Json::Num(winner.throughput(resp.n_devices)),
                    );
                    o.insert("nodes".into(),
                             Json::Num(stats.nodes as f64));
                    o.insert("complete".into(),
                             Json::Bool(stats.complete));
                    o.insert(
                        "candidates".into(),
                        Json::Arr(
                            plans
                                .iter()
                                .map(|p| {
                                    let mut c = BTreeMap::new();
                                    c.insert("batch".into(),
                                             Json::Num(p.batch as f64));
                                    c.insert(
                                        "throughput".into(),
                                        Json::Num(p.throughput(
                                            resp.n_devices)),
                                    );
                                    c.insert("peak_bytes".into(),
                                             Json::Num(p.cost.peak_mem));
                                    Json::Obj(c)
                                })
                                .collect(),
                        ),
                    );
                }
            }
        }
    }
    json::to_string(&Json::Obj(o))
}

/// Render a capacity sweep: one compact candidate object per rung of
/// the device ladder, plus `fits_min_devices` — the smallest cluster
/// that still held a feasible plan (`null` when nothing fit).
pub fn render_capacity(
    rungs: &Result<Vec<super::CapacityCandidate>, PlanError>,
) -> String {
    let mut o = BTreeMap::new();
    match rungs {
        Err(e) => {
            o.insert("ok".into(), Json::Bool(false));
            o.insert("error".into(), Json::Str(e.kind().into()));
            o.insert("detail".into(), Json::Str(e.to_string()));
        }
        Ok(rungs) => {
            o.insert("ok".into(), Json::Bool(true));
            o.insert("kind".into(), Json::Str("capacity".into()));
            o.insert(
                "fits_min_devices".into(),
                rungs
                    .iter()
                    .filter(|r| r.outcome.is_ok())
                    .map(|r| r.devices)
                    .min()
                    .map_or(Json::Null, |d| Json::Num(d as f64)),
            );
            o.insert(
                "candidates".into(),
                Json::Arr(
                    rungs
                        .iter()
                        .map(|r| {
                            let mut c = BTreeMap::new();
                            c.insert("devices".into(),
                                     Json::Num(r.devices as f64));
                            match &r.outcome {
                                Ok(resp) => {
                                    let plan = match &resp.answer {
                                        Answer::Plan { plan, .. } => plan,
                                        Answer::Sweep {
                                            plans, best, ..
                                        } => &plans[*best],
                                    };
                                    c.insert("ok".into(),
                                             Json::Bool(true));
                                    c.insert(
                                        "batch".into(),
                                        Json::Num(plan.batch as f64),
                                    );
                                    c.insert(
                                        "throughput".into(),
                                        Json::Num(plan.throughput(
                                            resp.n_devices)),
                                    );
                                    c.insert(
                                        "source".into(),
                                        Json::Str(resp.source.label()
                                                      .into()),
                                    );
                                }
                                Err(e) => {
                                    c.insert("ok".into(),
                                             Json::Bool(false));
                                    c.insert(
                                        "error".into(),
                                        Json::Str(e.kind().into()),
                                    );
                                }
                            }
                            Json::Obj(c)
                        })
                        .collect(),
                ),
            );
        }
    }
    json::to_string(&Json::Obj(o))
}

fn render_stats(service: &PlanService, telemetry: Option<&Telemetry>)
                -> String {
    let s = service.stats();
    let mut o = BTreeMap::new();
    o.insert("ok".into(), Json::Bool(true));
    o.insert("kind".into(), Json::Str("stats".into()));
    o.insert("cache_entries".into(),
             Json::Num(service.cache_len() as f64));
    o.insert("breaker".into(),
             Json::Str(service.breaker_state().into()));
    for (name, v) in s.fields() {
        o.insert(name.into(), Json::Num(v as f64));
    }
    if let Some(t) = telemetry {
        o.insert("telemetry".into(), t.to_json());
    }
    json::to_string(&Json::Obj(o))
}

/// Render the `metrics` verb: the Prometheus text exposition wrapped in
/// the protocol's one-JSON-line envelope (`text` carries the page; the
/// `--metrics-listen` HTTP endpoint serves the same page raw). Without
/// wire telemetry the latency lanes render as empty histograms rather
/// than vanishing — scrapers see a stable metric set either way.
fn render_metrics_line(service: &PlanService,
                       telemetry: Option<&Telemetry>) -> String {
    let fallback = Telemetry::new();
    let text = super::telemetry::render_prometheus(
        &service.stats(),
        service.cache_len(),
        telemetry.unwrap_or(&fallback),
        service.breaker_state(),
        service.tracer().span_histograms(),
    );
    let mut o = BTreeMap::new();
    o.insert("ok".into(), Json::Bool(true));
    o.insert("kind".into(), Json::Str("metrics".into()));
    o.insert("text".into(), Json::Str(text));
    json::to_string(&Json::Obj(o))
}

/// Render the `trace` verb: the completed-trace ring as one-line
/// summaries, or (with an id) one trace's full span tree and
/// convergence timeline.
fn render_trace(service: &PlanService, id: Option<&str>) -> String {
    let tracer = service.tracer();
    let mut o = BTreeMap::new();
    match id {
        None => {
            o.insert("ok".into(), Json::Bool(true));
            o.insert("kind".into(), Json::Str("traces".into()));
            o.insert("enabled".into(),
                     Json::Bool(super::trace::Tracer::enabled()));
            o.insert("traces".into(), Json::Arr(tracer.recent()));
        }
        Some(id) => match tracer.get(id) {
            Some(t) => {
                o.insert("ok".into(), Json::Bool(true));
                o.insert("kind".into(), Json::Str("trace".into()));
                o.insert("trace".into(), t.to_json());
            }
            None => {
                o.insert("ok".into(), Json::Bool(false));
                o.insert("error".into(), Json::Str("not-found".into()));
                o.insert(
                    "detail".into(),
                    Json::Str(format!(
                        "no trace '{id}' in the ring (the last {} \
                         completed traces are kept)",
                        super::trace::RING_CAP
                    )),
                );
            }
        },
    }
    json::to_string(&Json::Obj(o))
}

/// Handle one protocol line; always returns exactly one JSON line (the
/// `quit`/`shutdown` acknowledgements included — the transport acts on
/// the returned [`LineOutcome`]). With a [`Telemetry`] attached, every
/// dispatched query is timed into its shape's histogram and the verdict
/// counters — exactly once, which is what makes the telemetry
/// consistency invariants (`histogram counts == queries`) exact.
pub fn handle_line_full(service: &PlanService,
                        telemetry: Option<&Telemetry>, line: &str)
                        -> (String, LineOutcome) {
    match parse_request(line) {
        Err(e) => {
            if let Some(t) = telemetry {
                t.bump(super::telemetry::Counter::BadRequests);
            }
            (render_response(&Err(e)), LineOutcome::Continue)
        }
        Ok(Request::Stats) => {
            (render_stats(service, telemetry), LineOutcome::Continue)
        }
        Ok(Request::Metrics) => {
            (render_metrics_line(service, telemetry),
             LineOutcome::Continue)
        }
        Ok(Request::Trace(id)) => {
            (render_trace(service, id.as_deref()), LineOutcome::Continue)
        }
        Ok(Request::Quit) => {
            (r#"{"kind":"bye","ok":true}"#.to_string(), LineOutcome::Quit)
        }
        Ok(Request::Shutdown) => (
            r#"{"kind":"shutdown","ok":true}"#.to_string(),
            LineOutcome::Shutdown,
        ),
        Ok(Request::Query(q)) => {
            let started = Instant::now();
            let outcome = service.query(&q);
            if let Some(t) = telemetry {
                let shape =
                    if matches!(q.shape, QueryShape::Sweep { .. }) {
                        ObservedShape::Sweep
                    } else {
                        ObservedShape::Batch
                    };
                t.observe_query(shape, started.elapsed().as_secs_f64(),
                                &outcome);
            }
            (render_response(&outcome), LineOutcome::Continue)
        }
        Ok(Request::Replan { query, new_cluster, sweep_clusters }) => {
            if sweep_clusters {
                // every rung is its own query; the sweep observes each
                // one itself so the telemetry invariants hold per rung
                // (a ladder-level observe here would double-count)
                let rungs = service.replan_sweep_clusters(
                    &query, &new_cluster, telemetry);
                (render_capacity(&rungs), LineOutcome::Continue)
            } else {
                let started = Instant::now();
                let outcome = service.replan(&query, &new_cluster);
                if let Some(t) = telemetry {
                    // replans land in their own latency lane: a replan
                    // pays cache-bypass costs a plain query never sees,
                    // so folding it into batch/sweep would skew both
                    t.observe_query(ObservedShape::Replan,
                                    started.elapsed().as_secs_f64(),
                                    &outcome);
                }
                (render_response(&outcome), LineOutcome::Continue)
            }
        }
    }
}

/// [`handle_line_full`] without telemetry, collapsed to the original
/// "stop reading?" boolean (both `quit` and `shutdown` stop a
/// single-connection loop).
pub fn handle_line(service: &PlanService, line: &str) -> (String, bool) {
    let (response, outcome) = handle_line_full(service, None, line);
    (response, outcome != LineOutcome::Continue)
}

/// The serve loop: read requests line by line, answer each with one
/// JSON line, stop at `quit`/`shutdown` or EOF. Blank lines and `#`
/// comments are ignored (scripts can be annotated).
pub fn serve_loop<R: BufRead, W: Write>(service: &PlanService, reader: R,
                                        writer: &mut W)
                                        -> std::io::Result<()> {
    serve_loop_with(service, None, reader, writer)
}

/// [`serve_loop`] with wire telemetry attached (the `--listen`-less
/// `osdp serve` still counts requests and latencies so `stats` tells
/// the same story on stdin as over a socket).
pub fn serve_loop_with<R: BufRead, W: Write>(
    service: &PlanService, telemetry: Option<&Telemetry>, reader: R,
    writer: &mut W,
) -> std::io::Result<()> {
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(t) = telemetry {
            t.bump(super::telemetry::Counter::Requests);
        }
        // The stdin loop has no supervisor: a panicking request (e.g.
        // an injected search fault) would kill the whole process. The
        // socket front-end deliberately lets the panic fly — its pool
        // resurrects the worker — but here the only safe answer is to
        // contain it and answer an internal error. Invariants hold: the
        // injection fires before any accounting, so the dead query was
        // never counted anywhere.
        let (response, outcome) = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                handle_line_full(service, telemetry, line)
            }),
        )
        .unwrap_or_else(|_| {
            (
                render_response(&Err(PlanError::Internal(
                    "request handler panicked".into(),
                ))),
                LineOutcome::Continue,
            )
        });
        writeln!(writer, "{response}")?;
        writer.flush()?;
        if outcome != LineOutcome::Continue {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_query_lines() {
        let r = parse_request(
            "query setting=gpt:1000,64,2,128,4 mem=4 batch=2 g=0,2 \
             threads=2 engine=bb ckpt no-warm",
        )
        .unwrap();
        let Request::Query(q) = r else { panic!("not a query") };
        assert_eq!(q.setting, "gpt:1000,64,2,128,4");
        assert_eq!(q.cluster.mem_gib, 4.0);
        assert_eq!(q.shape, QueryShape::Batch(2));
        assert_eq!(q.search.granularities, vec![0, 2]);
        assert_eq!(q.threads, 2);
        assert_eq!(q.engine, Engine::FoldedBb);
        assert!(q.search.checkpointing);
        assert!(!q.warm);
        assert!(q.search.paper_granularity, "coarse by default");
    }

    #[test]
    fn parses_sweep_lines_and_verbs() {
        let r = parse_request(
            "sweep setting=48L/1024H mem=8 batch-cap=16 fine no-scopes",
        )
        .unwrap();
        let Request::Query(q) = r else { panic!("not a query") };
        assert_eq!(q.shape, QueryShape::Sweep { max_batch: 16 });
        assert!(!q.search.paper_granularity);
        assert!(!q.search.hybrid_scopes);
        assert_eq!(parse_request("stats").unwrap(), Request::Stats);
        assert_eq!(parse_request("quit").unwrap(), Request::Quit);
        assert_eq!(parse_request("exit").unwrap(), Request::Quit);
    }

    #[test]
    fn parses_replan_lines() {
        let r = parse_request(
            "replan setting=x mem=8 batch=2 devices=8 g=0 new-devices=4",
        )
        .unwrap();
        let Request::Replan { query, new_cluster, sweep_clusters } = r
        else {
            panic!("not a replan");
        };
        assert_eq!(query.shape, QueryShape::Batch(2));
        assert_eq!(query.cluster.devices, Some(8));
        assert_eq!(new_cluster.preset, "rtx_titan");
        assert_eq!(new_cluster.devices, Some(4));
        assert_eq!(new_cluster.mem_gib, 8.0, "mem carries over");
        assert!(!sweep_clusters);

        // sweep-shaped replan; a preset change drops the old device
        // count (it may not apply to the new topology)
        let r = parse_request(
            "replan setting=x mem=8 batch-cap=4 devices=4 g=0 \
             new-cluster=two_server_a100 new-mem=16",
        )
        .unwrap();
        let Request::Replan { query, new_cluster, sweep_clusters } = r
        else {
            panic!("not a replan");
        };
        assert_eq!(query.shape, QueryShape::Sweep { max_batch: 4 });
        assert_eq!(new_cluster.preset, "two_server_a100");
        assert_eq!(new_cluster.devices, None);
        assert_eq!(new_cluster.mem_gib, 16.0);
        assert!(!sweep_clusters);

        // sweep-clusters alone is a valid "what do I still fit on?"
        let r = parse_request(
            "replan setting=x mem=8 batch=1 g=0 sweep-clusters",
        )
        .unwrap();
        let Request::Replan { new_cluster, sweep_clusters, .. } = r
        else {
            panic!("not a replan");
        };
        assert!(sweep_clusters);
        assert_eq!(new_cluster.preset, "rtx_titan");
    }

    #[test]
    fn rejects_malformed_lines() {
        for bad in [
            "",
            "frobnicate x=1",
            "query batch=1",                       // missing setting
            "query setting=x",                     // missing batch
            "query setting=x batch=nope",
            "query setting=x batch=1 mem=wat",
            "query setting=x batch=1 bogus=1",     // unknown key
            "query setting=x batch=1 batch-cap=4", // sweep-only key
            "sweep setting=x batch=4",             // query-only key
            "query setting=x batch=1 engine=warp",
            "query setting=x batch=1 g=1,x",
            "replan setting=x g=0 new-devices=4",  // no batch/batch-cap
            "replan setting=x batch=1 batch-cap=4 new-devices=2", // both
            "replan setting=x batch=1 g=0",        // nothing changes
            "replan setting=x batch=1 new-devices=zero",
            "query setting=x batch=1 new-devices=2", // replan-only key
            "query setting=x batch=1 sweep-clusters",
            "sweep setting=x new-mem=4",
        ] {
            assert!(
                matches!(parse_request(bad),
                         Err(PlanError::BadRequest(_))),
                "'{bad}' must be rejected"
            );
        }
    }

    #[test]
    fn shutdown_verb_parses_and_acknowledges() {
        assert_eq!(parse_request("shutdown").unwrap(), Request::Shutdown);
        let service = super::super::PlanService::in_memory();
        let (resp, outcome) = handle_line_full(&service, None, "shutdown");
        assert_eq!(outcome, LineOutcome::Shutdown);
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true));
        assert_eq!(v.get("kind").as_str(), Some("shutdown"));
        // the boolean compat surface stops on shutdown too
        assert!(handle_line(&service, "shutdown").1);
        assert!(handle_line(&service, "quit").1);
        assert!(!handle_line(&service, "stats").1);
    }

    #[test]
    fn request_lines_round_trip_through_the_parser() {
        for line in [
            "query setting=gpt:1000,64,2,128,4 mem=4 batch=2 g=0,2 \
             threads=2 engine=bb ckpt no-warm",
            "query setting=48L/1024H mem=8 batch=1 g=0,4",
            "query setting=x mem=8.5 batch=3 devices=4 g=0 fine",
            "sweep setting=x mem=8 batch-cap=16 cluster=two_server_a100 \
             g=0,4 no-scopes",
            "sweep setting=x mem=8 batch-cap=64 g=0,4",
        ] {
            let Request::Query(q) = parse_request(line).unwrap() else {
                panic!("not a query: {line}");
            };
            let canon = request_line(&q).expect("expressible");
            let Request::Query(q2) = parse_request(&canon).unwrap() else {
                panic!("canonical line failed to parse: {canon}");
            };
            assert_eq!(q, q2, "round trip diverged for '{line}'");
        }
        // inexpressible settings refuse rather than emit a corrupt line
        let mut q = PlanQuery::batch("two words", 8.0, 1);
        assert_eq!(request_line(&q), None);
        q.setting = String::new();
        assert_eq!(request_line(&q), None);
        // the unfolded engine degrades to its folded ground-truth twin
        let mut q = PlanQuery::batch("x", 8.0, 1);
        q.engine = Engine::UnfoldedBb;
        let Request::Query(q2) =
            parse_request(&request_line(&q).unwrap()).unwrap()
        else {
            panic!("not a query");
        };
        assert_eq!(q2.engine, Engine::FoldedBb);
    }

    const TINY: &str = "gpt:1000,64,2,128,4";

    #[test]
    fn replan_verb_answers_like_a_cold_query_on_the_new_cluster() {
        let service = super::super::PlanService::in_memory();
        let (warm, _) = handle_line_full(
            &service,
            None,
            &format!("query setting={TINY} mem=8 batch=2 devices=8 g=0"),
        );
        assert_eq!(Json::parse(&warm).unwrap().get("ok").as_bool(),
                   Some(true));
        let (resp, outcome) = handle_line_full(
            &service,
            None,
            &format!("replan setting={TINY} mem=8 batch=2 devices=8 \
                      g=0 new-devices=4"),
        );
        assert_eq!(outcome, LineOutcome::Continue);
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true));
        assert_eq!(v.get("kind").as_str(), Some("plan"));
        assert_eq!(service.stats().replans, 1);

        // bit-identical to a cold query on the new cluster
        let cold = super::super::PlanService::in_memory();
        let (cresp, _) = handle_line_full(
            &cold,
            None,
            &format!("query setting={TINY} mem=8 batch=2 devices=4 g=0"),
        );
        let cv = Json::parse(&cresp).unwrap();
        assert_eq!(v.get("choice"), cv.get("choice"));
        assert_eq!(v.get("time_s").as_f64().map(f64::to_bits),
                   cv.get("time_s").as_f64().map(f64::to_bits));
        assert_eq!(v.get("key"), cv.get("key"));
    }

    #[test]
    fn capacity_sweep_renders_every_rung_of_the_ladder() {
        let service = super::super::PlanService::in_memory();
        let (resp, outcome) = handle_line_full(
            &service,
            None,
            &format!("replan setting={TINY} mem=8 batch=1 devices=8 \
                      g=0 sweep-clusters"),
        );
        assert_eq!(outcome, LineOutcome::Continue);
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true));
        assert_eq!(v.get("kind").as_str(), Some("capacity"));
        let rungs = v.get("candidates").as_arr().unwrap();
        assert_eq!(rungs.len(), 4, "8 → 4 → 2 → 1");
        for (rung, want) in rungs.iter().zip([8usize, 4, 2, 1]) {
            assert_eq!(rung.get("devices").as_usize(), Some(want));
            assert_eq!(rung.get("ok").as_bool(), Some(true),
                       "the tiny model fits everywhere at 8 GiB");
        }
        assert_eq!(v.get("fits_min_devices").as_usize(), Some(1));
        assert_eq!(service.stats().replans, 4);
    }

    #[test]
    fn trace_and_metrics_verbs_answer_json() {
        let service = super::super::PlanService::in_memory();
        // empty ring before any query is served
        let (resp, outcome) = handle_line_full(&service, None, "trace");
        assert_eq!(outcome, LineOutcome::Continue);
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true));
        assert_eq!(v.get("kind").as_str(), Some("traces"));
        assert_eq!(v.get("traces").as_arr(), Some(&[][..]));

        // serve one query; its trace_id resolves to a complete tree
        let (qresp, _) = handle_line_full(
            &service,
            None,
            &format!("query setting={TINY} mem=8 batch=2 g=0"),
        );
        let qv = Json::parse(&qresp).unwrap();
        let id =
            qv.get("trace_id").as_str().expect("trace_id").to_string();
        let (tresp, _) =
            handle_line_full(&service, None, &format!("trace {id}"));
        let tv = Json::parse(&tresp).unwrap();
        assert_eq!(tv.get("ok").as_bool(), Some(true));
        assert_eq!(tv.get("kind").as_str(), Some("trace"));
        assert_eq!(tv.get("trace").get("id").as_str(),
                   Some(id.as_str()));
        assert_eq!(tv.get("trace").get("complete").as_bool(),
                   Some(true));

        // unknown ids answer not-found; extra tokens are rejected
        let (miss, _) = handle_line_full(&service, None, "trace nope");
        assert_eq!(Json::parse(&miss).unwrap().get("error").as_str(),
                   Some("not-found"));
        let (bad, _) = handle_line_full(&service, None, "trace a b");
        assert_eq!(Json::parse(&bad).unwrap().get("ok").as_bool(),
                   Some(false));

        // metrics wraps the Prometheus page in the JSON envelope
        let (mresp, _) = handle_line_full(&service, None, "metrics");
        let mv = Json::parse(&mresp).unwrap();
        assert_eq!(mv.get("ok").as_bool(), Some(true));
        assert_eq!(mv.get("kind").as_str(), Some("metrics"));
        let text = mv.get("text").as_str().unwrap();
        assert!(text.contains("osdp_service_queries_total 1"),
                "the served query must show up in the exposition");
        assert!(text.contains("osdp_breaker_state{state=\"closed\"} 1"));
    }

    #[test]
    fn error_rendering_is_json() {
        let out = render_response(&Err(PlanError::UnknownSetting(
            "x".into(),
        )));
        let v = Json::parse(&out).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert_eq!(v.get("error").as_str(), Some("unknown-setting"));
    }
}
