//! The plan service's wire surface: a line-oriented request loop (one
//! request per line in, one JSON document per line out) suitable for
//! scripting, piping, and tests — `osdp serve` binds it to
//! stdin/stdout, `osdp query` runs a single request through the same
//! code path.
//!
//! ```text
//! query setting=48L/1024H mem=8 batch=4 [devices=8] [cluster=PRESET]
//!       [g=0,4] [engine=frontier|bb] [threads=N] [ckpt] [fine]
//!       [no-scopes] [no-warm]
//! sweep setting=48L/1024H mem=8 [batch-cap=64] [...same knobs]
//! stats
//! quit
//! ```
//!
//! Settings are zoo names (`48L/1024H`) or custom
//! `gpt:vocab,seq,layers,hidden,heads` specs. Malformed requests answer
//! `{"ok":false,"error":"bad-request",...}` — the loop never panics and
//! never exits on bad input (error-path property tests in
//! `rust/tests/plan_service.rs`).

use super::{Answer, PlanError, PlanQuery, PlanService, QueryResponse,
            QueryShape};
use crate::planner::Engine;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::io::{BufRead, Write};

/// One parsed protocol line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Query(PlanQuery),
    Stats,
    Quit,
}

/// Parse a protocol line. Strict: unknown keys are rejected so typos
/// fail loudly instead of planning the wrong thing.
pub fn parse_request(line: &str) -> Result<Request, PlanError> {
    let mut toks = line.split_whitespace();
    let verb = toks
        .next()
        .ok_or_else(|| PlanError::BadRequest("empty request".into()))?;
    match verb {
        "stats" => Ok(Request::Stats),
        "quit" | "exit" => Ok(Request::Quit),
        "query" | "sweep" => parse_query(verb, toks),
        other => Err(PlanError::BadRequest(format!(
            "unknown verb '{other}' (query | sweep | stats | quit)"
        ))),
    }
}

fn parse_query<'a>(verb: &str, toks: impl Iterator<Item = &'a str>)
                   -> Result<Request, PlanError> {
    let bad = PlanError::BadRequest;
    let mut q = PlanQuery::batch("", 8.0, 1);
    let mut setting = None;
    let mut batch = None;
    let mut batch_cap = 64usize;
    for tok in toks {
        match tok.split_once('=') {
            Some(("setting", v)) => setting = Some(v.to_string()),
            Some(("mem", v)) => {
                q.cluster.mem_gib = v
                    .parse()
                    .map_err(|_| bad(format!("mem: bad number '{v}'")))?;
            }
            Some(("devices", v)) => {
                q.cluster.devices = Some(parse_usize("devices", v)?);
            }
            Some(("cluster", v)) => q.cluster.preset = v.to_string(),
            Some(("g", v)) => {
                q.search.granularities = v
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(|s| parse_usize("g", s.trim()))
                    .collect::<Result<_, _>>()?;
            }
            Some(("engine", v)) => {
                q.engine = Engine::parse(v).ok_or_else(|| {
                    bad(format!("engine: want frontier|bb, got '{v}'"))
                })?;
            }
            Some(("threads", v)) => q.threads = parse_usize("threads", v)?,
            Some(("batch", v)) if verb == "query" => {
                batch = Some(parse_usize("batch", v)?);
            }
            Some(("batch-cap", v)) if verb == "sweep" => {
                batch_cap = parse_usize("batch-cap", v)?;
            }
            None if tok == "ckpt" => q.search.checkpointing = true,
            None if tok == "fine" => q.search.paper_granularity = false,
            None if tok == "no-scopes" => q.search.hybrid_scopes = false,
            None if tok == "no-warm" => q.warm = false,
            _ => {
                return Err(bad(format!(
                    "unexpected parameter '{tok}' for '{verb}'"
                )));
            }
        }
    }
    q.setting = setting
        .ok_or_else(|| bad("missing required setting=...".to_string()))?;
    // the shape is the single source of truth for the sweep cap
    // (SearchConfig::max_batch is unread on the service path)
    q.shape = match verb {
        "query" => QueryShape::Batch(
            batch.ok_or_else(|| bad("query needs batch=N".to_string()))?,
        ),
        _ => QueryShape::Sweep { max_batch: batch_cap },
    };
    Ok(Request::Query(q))
}

fn parse_usize(key: &str, v: &str) -> Result<usize, PlanError> {
    v.parse().map_err(|_| {
        PlanError::BadRequest(format!("{key}: bad integer '{v}'"))
    })
}

/// Render a query outcome as the single-line JSON the protocol speaks.
pub fn render_response(outcome: &Result<QueryResponse, PlanError>)
                       -> String {
    let mut o = BTreeMap::new();
    match outcome {
        Err(e) => {
            o.insert("ok".into(), Json::Bool(false));
            o.insert("error".into(), Json::Str(e.kind().into()));
            o.insert("detail".into(), Json::Str(e.to_string()));
        }
        Ok(resp) => {
            o.insert("ok".into(), Json::Bool(true));
            o.insert("source".into(),
                     Json::Str(resp.source.label().into()));
            o.insert("key".into(), Json::Str(resp.key.id()));
            match &resp.answer {
                Answer::Plan { plan, stats } => {
                    o.insert("kind".into(), Json::Str("plan".into()));
                    o.insert("batch".into(),
                             Json::Num(plan.batch as f64));
                    o.insert("time_s".into(), Json::Num(plan.cost.time));
                    o.insert("peak_bytes".into(),
                             Json::Num(plan.cost.peak_mem));
                    o.insert(
                        "throughput".into(),
                        Json::Num(plan.throughput(resp.n_devices)),
                    );
                    o.insert("nodes".into(),
                             Json::Num(stats.nodes as f64));
                    o.insert("complete".into(),
                             Json::Bool(stats.complete));
                    o.insert(
                        "choice".into(),
                        Json::Arr(plan.choice.iter()
                                      .map(|&c| Json::Num(c as f64))
                                      .collect()),
                    );
                }
                Answer::Sweep { plans, best, stats } => {
                    let winner = &plans[*best];
                    o.insert("kind".into(), Json::Str("sweep".into()));
                    o.insert("best_batch".into(),
                             Json::Num(winner.batch as f64));
                    o.insert(
                        "throughput".into(),
                        Json::Num(winner.throughput(resp.n_devices)),
                    );
                    o.insert("nodes".into(),
                             Json::Num(stats.nodes as f64));
                    o.insert("complete".into(),
                             Json::Bool(stats.complete));
                    o.insert(
                        "candidates".into(),
                        Json::Arr(
                            plans
                                .iter()
                                .map(|p| {
                                    let mut c = BTreeMap::new();
                                    c.insert("batch".into(),
                                             Json::Num(p.batch as f64));
                                    c.insert(
                                        "throughput".into(),
                                        Json::Num(p.throughput(
                                            resp.n_devices)),
                                    );
                                    c.insert("peak_bytes".into(),
                                             Json::Num(p.cost.peak_mem));
                                    Json::Obj(c)
                                })
                                .collect(),
                        ),
                    );
                }
            }
        }
    }
    json::to_string(&Json::Obj(o))
}

fn render_stats(service: &PlanService) -> String {
    let s = service.stats();
    let mut o = BTreeMap::new();
    o.insert("ok".into(), Json::Bool(true));
    o.insert("kind".into(), Json::Str("stats".into()));
    o.insert("cache_entries".into(),
             Json::Num(service.cache_len() as f64));
    for (name, v) in [
        ("hits", s.hits),
        ("misses", s.misses),
        ("inserts", s.inserts),
        ("evictions", s.evictions),
        ("stale_rejected", s.stale_rejected),
        ("coalesced", s.coalesced),
        ("planner_runs", s.planner_runs),
        ("warm_seeded", s.warm_seeded),
        ("warm_infeasible", s.warm_infeasible),
        ("persist_errors", s.persist_errors),
    ] {
        o.insert(name.into(), Json::Num(v as f64));
    }
    json::to_string(&Json::Obj(o))
}

/// Handle one protocol line; always returns exactly one JSON line (the
/// `quit` acknowledgement included — the caller decides to stop on
/// [`Request::Quit`]).
pub fn handle_line(service: &PlanService, line: &str) -> (String, bool) {
    match parse_request(line) {
        Err(e) => (render_response(&Err(e)), false),
        Ok(Request::Stats) => (render_stats(service), false),
        Ok(Request::Quit) => {
            (r#"{"kind":"bye","ok":true}"#.to_string(), true)
        }
        Ok(Request::Query(q)) => {
            (render_response(&service.query(&q)), false)
        }
    }
}

/// The serve loop: read requests line by line, answer each with one
/// JSON line, stop at `quit` or EOF. Blank lines and `#` comments are
/// ignored (scripts can be annotated).
pub fn serve_loop<R: BufRead, W: Write>(service: &PlanService, reader: R,
                                        writer: &mut W)
                                        -> std::io::Result<()> {
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (response, quit) = handle_line(service, line);
        writeln!(writer, "{response}")?;
        writer.flush()?;
        if quit {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_query_lines() {
        let r = parse_request(
            "query setting=gpt:1000,64,2,128,4 mem=4 batch=2 g=0,2 \
             threads=2 engine=bb ckpt no-warm",
        )
        .unwrap();
        let Request::Query(q) = r else { panic!("not a query") };
        assert_eq!(q.setting, "gpt:1000,64,2,128,4");
        assert_eq!(q.cluster.mem_gib, 4.0);
        assert_eq!(q.shape, QueryShape::Batch(2));
        assert_eq!(q.search.granularities, vec![0, 2]);
        assert_eq!(q.threads, 2);
        assert_eq!(q.engine, Engine::FoldedBb);
        assert!(q.search.checkpointing);
        assert!(!q.warm);
        assert!(q.search.paper_granularity, "coarse by default");
    }

    #[test]
    fn parses_sweep_lines_and_verbs() {
        let r = parse_request(
            "sweep setting=48L/1024H mem=8 batch-cap=16 fine no-scopes",
        )
        .unwrap();
        let Request::Query(q) = r else { panic!("not a query") };
        assert_eq!(q.shape, QueryShape::Sweep { max_batch: 16 });
        assert!(!q.search.paper_granularity);
        assert!(!q.search.hybrid_scopes);
        assert_eq!(parse_request("stats").unwrap(), Request::Stats);
        assert_eq!(parse_request("quit").unwrap(), Request::Quit);
        assert_eq!(parse_request("exit").unwrap(), Request::Quit);
    }

    #[test]
    fn rejects_malformed_lines() {
        for bad in [
            "",
            "frobnicate x=1",
            "query batch=1",                       // missing setting
            "query setting=x",                     // missing batch
            "query setting=x batch=nope",
            "query setting=x batch=1 mem=wat",
            "query setting=x batch=1 bogus=1",     // unknown key
            "query setting=x batch=1 batch-cap=4", // sweep-only key
            "sweep setting=x batch=4",             // query-only key
            "query setting=x batch=1 engine=warp",
            "query setting=x batch=1 g=1,x",
        ] {
            assert!(
                matches!(parse_request(bad),
                         Err(PlanError::BadRequest(_))),
                "'{bad}' must be rejected"
            );
        }
    }

    #[test]
    fn error_rendering_is_json() {
        let out = render_response(&Err(PlanError::UnknownSetting(
            "x".into(),
        )));
        let v = Json::parse(&out).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert_eq!(v.get("error").as_str(), Some("unknown-setting"));
    }
}
