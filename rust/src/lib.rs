//! # OSDP: Optimal Sharded Data Parallel
//!
//! A reproduction of *OSDP: Optimal Sharded Data Parallel for Distributed
//! Deep Learning* (Jiang et al., IJCAI 2023) as a three-layer
//! rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's system: per-operator DP/ZDP mode
//!   search under a device memory limit ([`planner`]) — an exact
//!   branch-and-bound, available serial ([`planner::dfs`]) or split
//!   across a `std::thread` worker pool with a shared atomic incumbent
//!   ([`planner::parallel`], bit-identical results at any thread count) —
//!   the (α,β,γ) cost model with dominance-pruned decision menus
//!   ([`cost`], [`cost::menu`]), operator splitting, baseline parallel
//!   strategies ([`parallel`]), a simulated multi-device fabric with real
//!   byte-moving ring collectives ([`fabric`], [`collectives`]), a
//!   discrete-event timeline simulator ([`sim`]), and a real training
//!   runtime executing AOT-compiled JAX/Pallas artifacts over PJRT
//!   ([`runtime`], [`train`]).
//! * **L2** — `python/compile/model.py`: GPT fwd/bwd/Adam in JAX.
//! * **L1** — `python/compile/kernels/`: Pallas kernels (operator-splitting
//!   matmul, tiled attention, layernorm).
//!
//! Python runs once at `make artifacts`; the binary is self-contained after.

pub mod bench;
pub mod cli;
pub mod collectives;
pub mod config;
pub mod cost;
pub mod fabric;
pub mod figures;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod parallel;
pub mod planner;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod train;
pub mod util;
