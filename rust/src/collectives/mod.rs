//! Ring collectives over the device fabric (paper §3.1: "we follow the
//! ring-based all-gather and reduce-scatter operations as supported by
//! NCCL"). Real f32 payloads move; the fabric's logical clocks charge the
//! (α, β) cost, so both numerics and timing are testable.
//!
//! All collectives are SPMD: every rank calls the same function in the
//! same order with equally-sized inputs.

pub mod hierarchical;
pub mod ring;

pub use hierarchical::{hier_all_gather, hier_all_reduce, node_all_gather,
                       node_grad_sync};
pub use ring::{all_gather, all_reduce, broadcast, reduce_scatter};

use crate::fabric::Endpoint;

/// Split `len` into `n` contiguous chunks (first `len % n` chunks get one
/// extra element) and return the (offset, size) of chunk `i`.
pub fn chunk_range(len: usize, n: usize, i: usize) -> (usize, usize) {
    debug_assert!(i < n);
    let base = len / n;
    let rem = len % n;
    let size = base + usize::from(i < rem);
    let offset = i * base + i.min(rem);
    (offset, size)
}

/// Analytic seconds for one ring collective of `k` rounds over `bytes`
/// payload on `n` devices — the quantity the paper's Eq. charges and the
/// fabric should approximately realize.
pub fn ring_model_seconds(k: f64, bytes: f64, n: usize, alpha: f64,
                          beta: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    k * (nf - 1.0) * (alpha + bytes * beta / nf)
}

/// Analytic seconds for the two-phase [`hierarchical::hier_all_gather`]
/// of `bytes` total payload over a uniform `(n, devices_per_node)`
/// layout: the intra phase forwards per-rank chunks around the node ring,
/// the inter phase exchanges whole node spans among same-local peers.
pub fn hier_gather_model_seconds(bytes: f64, n: usize, dpn: usize,
                                 alpha_intra: f64, beta_intra: f64,
                                 alpha_inter: f64, beta_inter: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    if dpn == 0 || n == dpn || n % dpn != 0 {
        // flat-ring fallback on the bottleneck link
        let (a, b) = if n > dpn {
            (alpha_inter, beta_inter)
        } else {
            (alpha_intra, beta_intra)
        };
        return ring_model_seconds(1.0, bytes, n, a, b);
    }
    let nodes = (n / dpn) as f64;
    let intra = (dpn as f64 - 1.0)
        * (alpha_intra + (bytes / n as f64) * beta_intra);
    let inter = (nodes - 1.0) * (alpha_inter + (bytes / nodes) * beta_inter);
    intra + inter
}

/// Helper trait so collectives can be written once over an [`Endpoint`].
pub trait Collective {
    fn ep(&mut self) -> &mut Endpoint;
}

impl Collective for Endpoint {
    fn ep(&mut self) -> &mut Endpoint {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_cover_exactly() {
        for len in [0usize, 1, 7, 16, 33] {
            for n in [1usize, 2, 3, 8] {
                let mut total = 0;
                let mut next = 0;
                for i in 0..n {
                    let (off, size) = chunk_range(len, n, i);
                    assert_eq!(off, next);
                    next = off + size;
                    total += size;
                }
                assert_eq!(total, len, "len={len} n={n}");
            }
        }
    }

    #[test]
    fn chunk_sizes_balanced() {
        // sizes differ by at most 1
        let sizes: Vec<usize> =
            (0..5).map(|i| chunk_range(17, 5, i).1).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 17);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn ring_model_matches_paper_formula() {
        // 2(N-1)(α + S·β/N) for DP grad sync
        let s = ring_model_seconds(2.0, 1e9, 8, 1e-5, 1e-10);
        let expect = 2.0 * 7.0 * (1e-5 + 1e9 * 1e-10 / 8.0);
        assert!((s - expect).abs() < 1e-12);
        assert_eq!(ring_model_seconds(3.0, 1e9, 1, 1e-5, 1e-10), 0.0);
    }
}
