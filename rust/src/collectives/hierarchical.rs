//! Two-level (hierarchical) collectives for multi-node clusters.
//!
//! A flat ring across two servers pays the slow inter-node link on every
//! step. The hierarchical schedule (NCCL-tree-like) does:
//!
//! 1. intra-node reduce-scatter (fast link),
//! 2. inter-node all-reduce among node leaders of each shard (slow link,
//!    but only `1/devices_per_node` of the data),
//! 3. intra-node all-gather (fast link).
//!
//! Used by the Figure 6 two-server experiments; the flat ring is the
//! baseline the paper's cost model assumes.

use super::ring::all_gather;
use super::chunk_range;
use crate::fabric::Endpoint;

/// Hierarchical all-reduce. Requires every node to hold the same number of
/// devices; falls back to the flat ring otherwise.
pub fn hier_all_reduce(ep: &mut Endpoint, data: &[f32]) -> Vec<f32> {
    let topo = topo_of(ep);
    let (n, dpn) = topo;
    if n == dpn || n % dpn != 0 {
        return super::ring::all_reduce(ep, data);
    }
    let n_nodes = n / dpn;
    let rank = ep.rank;
    let node = rank / dpn;
    let local = rank % dpn;

    // Phase 1: intra-node reduce-scatter over the node's subgroup.
    let shard = subgroup_reduce_scatter(ep, data, node * dpn, dpn, local);

    // Phase 2: cross-node all-reduce of this shard among same-`local` peers
    // (a ring of node leaders for this shard).
    let reduced = subgroup_all_reduce_strided(ep, &shard, local, dpn, n_nodes,
                                              node);

    // Phase 3: intra-node all-gather of the shards.
    subgroup_all_gather(ep, &reduced, data.len(), node * dpn, dpn, local)
}

/// Hierarchical all-gather of per-rank shards (chunk `rank` of
/// `total_len`): intra-node gather then inter-node exchange.
pub fn hier_all_gather(ep: &mut Endpoint, shard: &[f32], total_len: usize)
                       -> Vec<f32> {
    // For gather the flat ring moves the same bytes over the bottleneck
    // link, so we reuse it; this wrapper exists so callers express intent
    // and future schedules can specialize.
    all_gather(ep, shard, total_len)
}

fn topo_of(ep: &Endpoint) -> (usize, usize) {
    // devices_per_node is encoded in the fabric topology: probe node_of
    // boundaries by rank arithmetic. The Endpoint doesn't expose the
    // topology directly, so we reconstruct dpn from link latencies is
    // overkill — instead the topology is available via Endpoint::n and the
    // convention that hierarchical callers pass clusters with uniform
    // nodes. We read it from the environment of the call via topology();
    (ep.n, ep.topology_devices_per_node())
}

// --- subgroup primitives -------------------------------------------------
// These re-implement the ring steps over a subset of ranks (contiguous
// intra-node group, or strided inter-node group) using explicit sends.

fn subgroup_reduce_scatter(ep: &mut Endpoint, data: &[f32], base: usize,
                           size: usize, local: usize) -> Vec<f32> {
    if size == 1 {
        return data.to_vec();
    }
    let tag0 = ep.next_op_tag();
    let next = base + (local + 1) % size;
    let prev = base + (local + size - 1) % size;
    let mut work = data.to_vec();
    for s in 0..size - 1 {
        let send_idx = (local + 2 * size - 1 - s) % size;
        let recv_idx = (local + 2 * size - 2 - s) % size;
        let (so, sl) = chunk_range(work.len(), size, send_idx);
        ep.send(next, tag0 + s as u64, work[so..so + sl].to_vec());
        let incoming = ep.recv(prev, tag0 + s as u64);
        let (ro, rl) = chunk_range(work.len(), size, recv_idx);
        debug_assert_eq!(incoming.len(), rl);
        for (w, x) in work[ro..ro + rl].iter_mut().zip(&incoming) {
            *w += x;
        }
    }
    let (o, l) = chunk_range(work.len(), size, local);
    work[o..o + l].to_vec()
}

fn subgroup_all_gather(ep: &mut Endpoint, shard: &[f32], total_len: usize,
                       base: usize, size: usize, local: usize) -> Vec<f32> {
    if size == 1 {
        return shard.to_vec();
    }
    let tag0 = ep.next_op_tag();
    let next = base + (local + 1) % size;
    let prev = base + (local + size - 1) % size;
    let mut out = vec![0.0f32; total_len];
    let (own_off, own_len) = chunk_range(total_len, size, local);
    debug_assert_eq!(shard.len(), own_len);
    out[own_off..own_off + own_len].copy_from_slice(shard);
    for s in 0..size - 1 {
        let send_idx = (local + size - s) % size;
        let recv_idx = (local + size - s - 1) % size;
        let (so, sl) = chunk_range(total_len, size, send_idx);
        ep.send(next, tag0 + s as u64, out[so..so + sl].to_vec());
        let incoming = ep.recv(prev, tag0 + s as u64);
        let (ro, rl) = chunk_range(total_len, size, recv_idx);
        debug_assert_eq!(incoming.len(), rl);
        out[ro..ro + rl].copy_from_slice(&incoming);
    }
    out
}

/// All-reduce among the `n_nodes` ranks `{local + k·stride}` (ring order by
/// node index `me`).
fn subgroup_all_reduce_strided(ep: &mut Endpoint, data: &[f32], local: usize,
                               stride: usize, n_nodes: usize, me: usize)
                               -> Vec<f32> {
    if n_nodes == 1 {
        return data.to_vec();
    }
    let rank_of = |node: usize| node * stride + local;
    let tag0 = ep.next_op_tag();
    let next = rank_of((me + 1) % n_nodes);
    let prev = rank_of((me + n_nodes - 1) % n_nodes);
    let mut work = data.to_vec();
    // reduce-scatter across nodes
    for s in 0..n_nodes - 1 {
        let send_idx = (me + 2 * n_nodes - 1 - s) % n_nodes;
        let recv_idx = (me + 2 * n_nodes - 2 - s) % n_nodes;
        let (so, sl) = chunk_range(work.len(), n_nodes, send_idx);
        ep.send(next, tag0 + s as u64, work[so..so + sl].to_vec());
        let incoming = ep.recv(prev, tag0 + s as u64);
        let (ro, rl) = chunk_range(work.len(), n_nodes, recv_idx);
        for (w, x) in work[ro..ro + rl].iter_mut().zip(&incoming) {
            *w += x;
        }
    }
    // all-gather across nodes: chunk c starts at node c (post reduce-
    // scatter ownership) and travels forward.
    let tag1 = ep.next_op_tag();
    for s in 0..n_nodes - 1 {
        let send_idx = (me + n_nodes - s) % n_nodes;
        let recv_idx = (me + n_nodes - 1 - s) % n_nodes;
        let (so, sl) = chunk_range(work.len(), n_nodes, send_idx);
        ep.send(next, tag1 + s as u64, work[so..so + sl].to_vec());
        let incoming = ep.recv(prev, tag1 + s as u64);
        let (ro, rl) = chunk_range(work.len(), n_nodes, recv_idx);
        debug_assert_eq!(incoming.len(), rl);
        work[ro..ro + rl].copy_from_slice(&incoming);
    }
    work
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{self, Topology};

    fn two_nodes(n: usize, dpn: usize) -> Topology {
        Topology {
            n_devices: n,
            devices_per_node: dpn,
            alpha_intra: 1e-6,
            beta_intra: 1e-11,
            alpha_inter: 1e-5,
            beta_inter: 1e-9,
        }
    }

    fn input(rank: usize, len: usize) -> Vec<f32> {
        (0..len).map(|i| ((rank + 1) * (i + 1)) as f32 * 0.5).collect()
    }

    #[test]
    fn hier_all_reduce_matches_flat_numerics() {
        for (n, dpn) in [(4usize, 2usize), (8, 4), (6, 3)] {
            let len = 37;
            let out = fabric::run(n, two_nodes(n, dpn), move |ep| {
                hier_all_reduce(ep, &input(ep.rank, len))
            });
            let mut want = vec![0.0f32; len];
            for r in 0..n {
                for (w, x) in want.iter_mut().zip(input(r, len)) {
                    *w += x;
                }
            }
            for got in out {
                for (g, e) in got.iter().zip(&want) {
                    assert!((g - e).abs() < 1e-2, "n={n} dpn={dpn}: {g} vs {e}");
                }
            }
        }
    }

    #[test]
    fn hier_beats_flat_ring_on_slow_inter_link() {
        let n = 8;
        let dpn = 4;
        let len = 1 << 16;
        let t_hier = fabric::run_timed(n, two_nodes(n, dpn), move |ep| {
            hier_all_reduce(ep, &vec![1.0f32; len]);
        });
        let t_flat = fabric::run_timed(n, two_nodes(n, dpn), move |ep| {
            super::super::ring::all_reduce(ep, &vec![1.0f32; len]);
        });
        let hier_max = t_hier.iter().map(|(_, t)| *t).fold(0.0, f64::max);
        let flat_max = t_flat.iter().map(|(_, t)| *t).fold(0.0, f64::max);
        assert!(hier_max < flat_max,
                "hier {hier_max} should beat flat {flat_max}");
    }

    #[test]
    fn falls_back_on_single_node() {
        let n = 4;
        let out = fabric::run(n, Topology::flat(n, 1e-6, 1e-9), move |ep| {
            hier_all_reduce(ep, &input(ep.rank, 11))
        });
        let mut want = vec![0.0f32; 11];
        for r in 0..n {
            for (w, x) in want.iter_mut().zip(input(r, 11)) {
                *w += x;
            }
        }
        for got in out {
            for (g, e) in got.iter().zip(&want) {
                assert!((g - e).abs() < 1e-3);
            }
        }
    }
}
