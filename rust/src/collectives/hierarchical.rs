//! Two-level (hierarchical) collectives for multi-node clusters.
//!
//! A flat ring across two servers pays the slow inter-node link on every
//! step. The hierarchical schedule (NCCL-tree-like) does:
//!
//! 1. intra-node reduce-scatter (fast link),
//! 2. inter-node all-reduce among node leaders of each shard (slow link,
//!    but only `1/devices_per_node` of the data),
//! 3. intra-node all-gather (fast link).
//!
//! Used by the Figure 6 two-server experiments; the flat ring is the
//! baseline the paper's cost model assumes.

use super::ring::all_gather;
use super::chunk_range;
use crate::fabric::Endpoint;

/// Hierarchical all-reduce. Requires every node to hold the same number of
/// devices; falls back to the flat ring otherwise.
pub fn hier_all_reduce(ep: &mut Endpoint, data: &[f32]) -> Vec<f32> {
    let topo = topo_of(ep);
    let (n, dpn) = topo;
    if n == dpn || n % dpn != 0 {
        return super::ring::all_reduce(ep, data);
    }
    let n_nodes = n / dpn;
    let rank = ep.rank;
    let node = rank / dpn;
    let local = rank % dpn;

    // Phase 1: intra-node reduce-scatter over the node's subgroup.
    let shard = subgroup_reduce_scatter(ep, data, node * dpn, dpn, local);

    // Phase 2: cross-node all-reduce of this shard among same-`local` peers
    // (a ring of node leaders for this shard).
    let reduced = subgroup_all_reduce_strided(ep, &shard, local, dpn, n_nodes,
                                              node);

    // Phase 3: intra-node all-gather of the shards.
    subgroup_all_gather(ep, &reduced, data.len(), node * dpn, dpn, local)
}

/// Hierarchical all-gather of per-rank shards (chunk `rank` of
/// `total_len`, per [`chunk_range`] over all `n` ranks): intra-node gather
/// then inter-node exchange.
///
/// 1. intra-node ring all-gather of the members' chunks (fast link) —
///    afterwards every device holds its node's whole contiguous span;
/// 2. inter-node ring exchange of whole node spans among same-`local`
///    peers (slow link): only `n_nodes - 1` slow-link steps per rank,
///    each moving one whole span — instead of the flat ring's `n - 1`
///    steps that can all stall on the slow hop.
///
/// Falls back to the flat ring on a single node or a non-uniform layout
/// (the latter is rejected by `Cluster::validate`, but a hand-built
/// `Topology` can still express it).
pub fn hier_all_gather(ep: &mut Endpoint, shard: &[f32], total_len: usize)
                       -> Vec<f32> {
    let (n, dpn) = topo_of(ep);
    if dpn == 0 || n == dpn || n % dpn != 0 {
        return all_gather(ep, shard, total_len);
    }
    let n_nodes = n / dpn;
    let rank = ep.rank;
    let node = rank / dpn;
    let local = rank % dpn;

    let mut out = vec![0.0f32; total_len];
    let (own_off, own_len) = chunk_range(total_len, n, rank);
    debug_assert_eq!(shard.len(), own_len, "shard size mismatch");
    out[own_off..own_off + own_len].copy_from_slice(shard);

    // Phase 1: intra-node ring all-gather of the node's per-rank chunks.
    if dpn > 1 {
        let base = node * dpn;
        let next = base + (local + 1) % dpn;
        let prev = base + (local + dpn - 1) % dpn;
        let tag0 = ep.next_op_tag();
        for s in 0..dpn - 1 {
            let send_rank = base + (local + dpn - s) % dpn;
            let recv_rank = base + (local + dpn - s - 1) % dpn;
            let (so, sl) = chunk_range(total_len, n, send_rank);
            ep.send(next, tag0 + s as u64, out[so..so + sl].to_vec());
            let incoming = ep.recv(prev, tag0 + s as u64);
            let (ro, rl) = chunk_range(total_len, n, recv_rank);
            debug_assert_eq!(incoming.len(), rl);
            out[ro..ro + rl].copy_from_slice(&incoming);
        }
    }

    // Phase 2: inter-node ring exchange of whole node spans among
    // same-`local` peers.
    let rank_of = |nd: usize| nd * dpn + local;
    let next = rank_of((node + 1) % n_nodes);
    let prev = rank_of((node + n_nodes - 1) % n_nodes);
    let tag1 = ep.next_op_tag();
    for s in 0..n_nodes - 1 {
        let send_node = (node + n_nodes - s) % n_nodes;
        let recv_node = (node + n_nodes - s - 1) % n_nodes;
        let (so, sl) = node_span(total_len, n, dpn, send_node);
        ep.send(next, tag1 + s as u64, out[so..so + sl].to_vec());
        let incoming = ep.recv(prev, tag1 + s as u64);
        let (ro, rl) = node_span(total_len, n, dpn, recv_node);
        debug_assert_eq!(incoming.len(), rl);
        out[ro..ro + rl].copy_from_slice(&incoming);
    }
    out
}

/// Node-scoped all-gather: gathers `shard` (chunk `local` of `total_len`
/// under the caller's node's `devices_per_node`-way partition) across the
/// caller's node *only* — the fabric realization of a node-scoped ZDP
/// parameter gather, where every node holds a full replica sharded among
/// its own devices and nothing crosses the inter-node link.
///
/// Requires a uniform node layout: the shard shape is defined by the
/// `devices_per_node`-way partition, so — unlike [`node_grad_sync`],
/// whose full-length input permits a flat-ring fallback — there is no
/// layout-agnostic degradation for a trailing partial node. Panics with
/// an explicit message on non-uniform topologies (which
/// `Cluster::validate` rejects; only hand-built [`Topology`]s can
/// express them).
///
/// [`Topology`]: crate::fabric::Topology
pub fn node_all_gather(ep: &mut Endpoint, shard: &[f32], total_len: usize)
                       -> Vec<f32> {
    let (n, dpn) = topo_of(ep);
    let dpn = dpn.min(n).max(1);
    assert!(
        n % dpn == 0,
        "node_all_gather requires a uniform node layout, got {n} devices \
         over nodes of {dpn} (Cluster::validate rejects such clusters)"
    );
    let node = ep.rank / dpn;
    let local = ep.rank % dpn;
    subgroup_all_gather(ep, shard, total_len, node * dpn, dpn, local)
}

/// Node-scoped ZDP gradient synchronization: intra-node reduce-scatter of
/// the full gradient (fast link) followed by the cross-node all-reduce of
/// the resulting shard among same-`local` peers (slow link, `1/dpn` of the
/// bytes) — the fabric realization of the cost model's node-scope gradient
/// term (`cost::time::inter_node_grad_time`). Returns this rank's
/// fully-reduced shard (chunk `local` of `data` under the node's
/// `devices_per_node`-way partition); on a single node that degenerates
/// to the flat reduce-scatter shape.
///
/// Like [`node_all_gather`], the *output* shape is defined by the
/// node partition, so a non-uniform layout has no shape-preserving
/// fallback — panics with an explicit message there (such clusters are
/// rejected by `Cluster::validate`; only hand-built topologies can
/// express them).
pub fn node_grad_sync(ep: &mut Endpoint, data: &[f32]) -> Vec<f32> {
    let (n, dpn) = topo_of(ep);
    let dpn = dpn.min(n).max(1);
    assert!(
        n % dpn == 0,
        "node_grad_sync requires a uniform node layout, got {n} devices \
         over nodes of {dpn} (Cluster::validate rejects such clusters)"
    );
    let n_nodes = n / dpn;
    let node = ep.rank / dpn;
    let local = ep.rank % dpn;
    let shard = subgroup_reduce_scatter(ep, data, node * dpn, dpn, local);
    subgroup_all_reduce_strided(ep, &shard, local, dpn, n_nodes, node)
}

/// (offset, len) of node `node`'s contiguous span of per-rank chunks —
/// the union of its members' [`chunk_range`] chunks (NOT
/// `chunk_range(total_len, n_nodes, node)`: the remainder distribution
/// differs).
fn node_span(total_len: usize, n: usize, dpn: usize, node: usize)
             -> (usize, usize) {
    let (lo_off, _) = chunk_range(total_len, n, node * dpn);
    let (hi_off, hi_len) = chunk_range(total_len, n, node * dpn + dpn - 1);
    (lo_off, hi_off + hi_len - lo_off)
}

/// `(n_devices, devices_per_node)` of the fabric the endpoint runs on.
/// The topology travels with the [`Endpoint`] itself (`Endpoint::n` plus
/// the `Topology` every device thread is spawned with), so hierarchical
/// schedules read the node shape directly instead of trying to
/// reconstruct node boundaries from link latencies.
fn topo_of(ep: &Endpoint) -> (usize, usize) {
    (ep.n, ep.topology_devices_per_node())
}

// --- subgroup primitives -------------------------------------------------
// These re-implement the ring steps over a subset of ranks (contiguous
// intra-node group, or strided inter-node group) using explicit sends.

fn subgroup_reduce_scatter(ep: &mut Endpoint, data: &[f32], base: usize,
                           size: usize, local: usize) -> Vec<f32> {
    if size == 1 {
        return data.to_vec();
    }
    let tag0 = ep.next_op_tag();
    let next = base + (local + 1) % size;
    let prev = base + (local + size - 1) % size;
    let mut work = data.to_vec();
    for s in 0..size - 1 {
        let send_idx = (local + 2 * size - 1 - s) % size;
        let recv_idx = (local + 2 * size - 2 - s) % size;
        let (so, sl) = chunk_range(work.len(), size, send_idx);
        ep.send(next, tag0 + s as u64, work[so..so + sl].to_vec());
        let incoming = ep.recv(prev, tag0 + s as u64);
        let (ro, rl) = chunk_range(work.len(), size, recv_idx);
        debug_assert_eq!(incoming.len(), rl);
        for (w, x) in work[ro..ro + rl].iter_mut().zip(&incoming) {
            *w += x;
        }
    }
    let (o, l) = chunk_range(work.len(), size, local);
    work[o..o + l].to_vec()
}

fn subgroup_all_gather(ep: &mut Endpoint, shard: &[f32], total_len: usize,
                       base: usize, size: usize, local: usize) -> Vec<f32> {
    if size == 1 {
        return shard.to_vec();
    }
    let tag0 = ep.next_op_tag();
    let next = base + (local + 1) % size;
    let prev = base + (local + size - 1) % size;
    let mut out = vec![0.0f32; total_len];
    let (own_off, own_len) = chunk_range(total_len, size, local);
    debug_assert_eq!(shard.len(), own_len);
    out[own_off..own_off + own_len].copy_from_slice(shard);
    for s in 0..size - 1 {
        let send_idx = (local + size - s) % size;
        let recv_idx = (local + size - s - 1) % size;
        let (so, sl) = chunk_range(total_len, size, send_idx);
        ep.send(next, tag0 + s as u64, out[so..so + sl].to_vec());
        let incoming = ep.recv(prev, tag0 + s as u64);
        let (ro, rl) = chunk_range(total_len, size, recv_idx);
        debug_assert_eq!(incoming.len(), rl);
        out[ro..ro + rl].copy_from_slice(&incoming);
    }
    out
}

/// All-reduce among the `n_nodes` ranks `{local + k·stride}` (ring order by
/// node index `me`).
fn subgroup_all_reduce_strided(ep: &mut Endpoint, data: &[f32], local: usize,
                               stride: usize, n_nodes: usize, me: usize)
                               -> Vec<f32> {
    if n_nodes == 1 {
        return data.to_vec();
    }
    let rank_of = |node: usize| node * stride + local;
    let tag0 = ep.next_op_tag();
    let next = rank_of((me + 1) % n_nodes);
    let prev = rank_of((me + n_nodes - 1) % n_nodes);
    let mut work = data.to_vec();
    // reduce-scatter across nodes
    for s in 0..n_nodes - 1 {
        let send_idx = (me + 2 * n_nodes - 1 - s) % n_nodes;
        let recv_idx = (me + 2 * n_nodes - 2 - s) % n_nodes;
        let (so, sl) = chunk_range(work.len(), n_nodes, send_idx);
        ep.send(next, tag0 + s as u64, work[so..so + sl].to_vec());
        let incoming = ep.recv(prev, tag0 + s as u64);
        let (ro, rl) = chunk_range(work.len(), n_nodes, recv_idx);
        for (w, x) in work[ro..ro + rl].iter_mut().zip(&incoming) {
            *w += x;
        }
    }
    // all-gather across nodes: chunk c starts at node c (post reduce-
    // scatter ownership) and travels forward.
    let tag1 = ep.next_op_tag();
    for s in 0..n_nodes - 1 {
        let send_idx = (me + n_nodes - s) % n_nodes;
        let recv_idx = (me + n_nodes - 1 - s) % n_nodes;
        let (so, sl) = chunk_range(work.len(), n_nodes, send_idx);
        ep.send(next, tag1 + s as u64, work[so..so + sl].to_vec());
        let incoming = ep.recv(prev, tag1 + s as u64);
        let (ro, rl) = chunk_range(work.len(), n_nodes, recv_idx);
        debug_assert_eq!(incoming.len(), rl);
        work[ro..ro + rl].copy_from_slice(&incoming);
    }
    work
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{self, Topology};

    fn two_nodes(n: usize, dpn: usize) -> Topology {
        Topology {
            n_devices: n,
            devices_per_node: dpn,
            alpha_intra: 1e-6,
            beta_intra: 1e-11,
            alpha_inter: 1e-5,
            beta_inter: 1e-9,
        }
    }

    fn input(rank: usize, len: usize) -> Vec<f32> {
        (0..len).map(|i| ((rank + 1) * (i + 1)) as f32 * 0.5).collect()
    }

    #[test]
    fn hier_all_reduce_matches_flat_numerics() {
        for (n, dpn) in [(4usize, 2usize), (8, 4), (6, 3)] {
            let len = 37;
            let out = fabric::run(n, two_nodes(n, dpn), move |ep| {
                hier_all_reduce(ep, &input(ep.rank, len))
            });
            let mut want = vec![0.0f32; len];
            for r in 0..n {
                for (w, x) in want.iter_mut().zip(input(r, len)) {
                    *w += x;
                }
            }
            for got in out {
                for (g, e) in got.iter().zip(&want) {
                    assert!((g - e).abs() < 1e-2, "n={n} dpn={dpn}: {g} vs {e}");
                }
            }
        }
    }

    #[test]
    fn hier_beats_flat_ring_on_slow_inter_link() {
        let n = 8;
        let dpn = 4;
        let len = 1 << 16;
        let t_hier = fabric::run_timed(n, two_nodes(n, dpn), move |ep| {
            hier_all_reduce(ep, &vec![1.0f32; len]);
        });
        let t_flat = fabric::run_timed(n, two_nodes(n, dpn), move |ep| {
            super::super::ring::all_reduce(ep, &vec![1.0f32; len]);
        });
        let hier_max = t_hier.iter().map(|(_, t)| *t).fold(0.0, f64::max);
        let flat_max = t_flat.iter().map(|(_, t)| *t).fold(0.0, f64::max);
        assert!(hier_max < flat_max,
                "hier {hier_max} should beat flat {flat_max}");
    }

    #[test]
    fn hier_all_gather_matches_flat_numerics_and_wins_on_time() {
        use super::super::chunk_range;
        for (n, dpn) in [(4usize, 2usize), (8, 4), (6, 3), (8, 2)] {
            let total = 1 << 14;
            let full: Vec<f32> =
                (0..total).map(|i| (i % 97) as f32 * 0.25).collect();
            let want = full.clone();
            let topo = two_nodes(n, dpn);
            let hier = fabric::run_timed(n, topo.clone(), move |ep| {
                let (o, l) = chunk_range(total, ep.n, ep.rank);
                hier_all_gather(ep, &full[o..o + l], total)
            });
            for (got, _) in &hier {
                assert_eq!(got, &want, "n={n} dpn={dpn}");
            }
            // the two-phase schedule beats the flat ring whose every step
            // can stall on the slow inter-node hop
            let flat = fabric::run_timed(n, topo, move |ep| {
                let (_, l) = chunk_range(total, ep.n, ep.rank);
                let shard = vec![1.0f32; l];
                all_gather(ep, &shard, total);
            });
            let t_hier =
                hier.iter().map(|(_, t)| *t).fold(0.0, f64::max);
            let t_flat =
                flat.iter().map(|(_, t)| *t).fold(0.0, f64::max);
            assert!(t_hier < t_flat,
                    "n={n} dpn={dpn}: hier {t_hier} vs flat {t_flat}");
        }
    }

    #[test]
    fn hier_all_gather_falls_back_to_flat_ring() {
        use super::super::chunk_range;
        // single node and non-uniform layouts take the flat path but stay
        // correct
        for (n, dpn) in [(4usize, 4usize), (6, 4)] {
            let total = 37;
            let full: Vec<f32> =
                (0..total).map(|i| (i + 3) as f32 * 0.5).collect();
            let want = full.clone();
            let out = fabric::run(n, two_nodes(n, dpn), move |ep| {
                let (o, l) = chunk_range(total, ep.n, ep.rank);
                hier_all_gather(ep, &full[o..o + l], total)
            });
            for got in out {
                assert_eq!(got, want, "n={n} dpn={dpn}");
            }
        }
    }

    #[test]
    fn node_all_gather_stays_inside_the_node() {
        // Each node gathers its own replica: ranks see their node's
        // concatenation, and no payload crosses the inter-node link.
        let (n, dpn) = (8usize, 4usize);
        let total = 40;
        let out = fabric::run(n, two_nodes(n, dpn), move |ep| {
            let node = ep.rank / dpn;
            let local = ep.rank % dpn;
            let full: Vec<f32> = (0..total)
                .map(|i| (node * 1000 + i) as f32)
                .collect();
            let (o, l) = super::super::chunk_range(total, dpn, local);
            let gathered = node_all_gather(ep, &full[o..o + l], total);
            (gathered, full, ep.bytes_sent)
        });
        let mut intra_bytes = 0u64;
        for (rank, (got, want, sent)) in out.into_iter().enumerate() {
            assert_eq!(got, want, "rank {rank}");
            intra_bytes += sent;
        }
        assert!(intra_bytes > 0);
        // cross-check against a timed run: inter-node latency never paid
        let t = fabric::run_timed(n, two_nodes(n, dpn), move |ep| {
            let local = ep.rank % dpn;
            let (_, l) = super::super::chunk_range(total, dpn, local);
            node_all_gather(ep, &vec![1.0f32; l], total);
        });
        let worst = t.iter().map(|(_, c)| *c).fold(0.0, f64::max);
        // 3 intra steps of ~(α_intra + chunk·β_intra): far below even one
        // inter-node α (1e-5 in two_nodes)
        assert!(worst < 1e-5, "node gather touched the slow link: {worst}");
    }

    #[test]
    #[should_panic(expected = "device thread panicked")]
    fn node_all_gather_rejects_non_uniform_layouts_loudly() {
        // 6 devices over nodes of 4 leaves a partial node; the shard shape
        // is ill-defined there, so the collective must fail with its
        // explicit layout assert (surfaced as a device-thread panic)
        // rather than a confusing slice-length mismatch deep inside.
        fabric::run(6, two_nodes(6, 4), move |ep| {
            let local = ep.rank % 4;
            let (_, l) = super::super::chunk_range(40, 4, local);
            node_all_gather(ep, &vec![1.0f32; l], 40)
        });
    }

    #[test]
    fn node_grad_sync_reduces_across_all_ranks() {
        // The returned shard must equal the global sum's shard — gradient
        // averaging is over all N data-parallel replicas even though the
        // states are sharded per node.
        let (n, dpn) = (8usize, 4usize);
        let len = 23;
        let out = fabric::run(n, two_nodes(n, dpn), move |ep| {
            let local = ep.rank % dpn;
            let shard = node_grad_sync(ep, &input(ep.rank, len));
            (local, shard)
        });
        let mut want = vec![0.0f32; len];
        for r in 0..n {
            for (w, x) in want.iter_mut().zip(input(r, len)) {
                *w += x;
            }
        }
        for (local, shard) in out {
            let (o, l) = super::super::chunk_range(len, dpn, local);
            for (g, e) in shard.iter().zip(&want[o..o + l]) {
                assert!((g - e).abs() < 1e-2, "{g} vs {e}");
            }
        }
    }

    #[test]
    fn falls_back_on_single_node() {
        let n = 4;
        let out = fabric::run(n, Topology::flat(n, 1e-6, 1e-9), move |ep| {
            hier_all_reduce(ep, &input(ep.rank, 11))
        });
        let mut want = vec![0.0f32; 11];
        for r in 0..n {
            for (w, x) in want.iter_mut().zip(input(r, 11)) {
                *w += x;
            }
        }
        for got in out {
            for (g, e) in got.iter().zip(&want) {
                assert!((g - e).abs() < 1e-3);
            }
        }
    }
}
