//! Flat ring collectives: reduce-scatter, all-gather, all-reduce, broadcast.
//!
//! The ring algorithms are the textbook NCCL ones: `N−1` steps, each rank
//! sending one `len/N` chunk to its ring successor per step. All-reduce is
//! explicitly composed as reduce-scatter + all-gather, mirroring Figure 1's
//! dissection of the DP gradient synchronization.

use super::chunk_range;
use crate::fabric::Endpoint;

/// Ring reduce-scatter: every rank contributes `data` (equal length across
/// ranks); rank `i` returns the element-wise sum of chunk `i`.
pub fn reduce_scatter(ep: &mut Endpoint, data: &[f32]) -> Vec<f32> {
    let n = ep.n;
    if n == 1 {
        return data.to_vec();
    }
    let tag0 = ep.next_op_tag();
    let rank = ep.rank;
    let next = ep.ring_next();
    let prev = ep.ring_prev();
    let mut work = data.to_vec();

    // Chunk c travels rank c+1 → c+2 → … → c, accumulating at each hop:
    // step s has rank r send chunk (r−1−s) and fold in chunk (r−2−s).
    // After N−1 steps, chunk `rank` holds the full sum.
    for s in 0..n - 1 {
        let send_idx = (rank + 2 * n - 1 - s) % n;
        let recv_idx = (rank + 2 * n - 2 - s) % n;
        let (so, sl) = chunk_range(work.len(), n, send_idx);
        ep.send(next, tag0 + s as u64, work[so..so + sl].to_vec());
        let incoming = ep.recv(prev, tag0 + s as u64);
        let (ro, rl) = chunk_range(work.len(), n, recv_idx);
        debug_assert_eq!(incoming.len(), rl);
        for (w, x) in work[ro..ro + rl].iter_mut().zip(&incoming) {
            *w += x;
        }
    }
    let (o, l) = chunk_range(work.len(), n, rank);
    work[o..o + l].to_vec()
}

/// Ring all-gather: rank `i` contributes `shard` (chunk `i` of the result,
/// sized per [`chunk_range`] of `total_len`); every rank returns the full
/// concatenation.
pub fn all_gather(ep: &mut Endpoint, shard: &[f32], total_len: usize)
                  -> Vec<f32> {
    let n = ep.n;
    if n == 1 {
        return shard.to_vec();
    }
    let tag0 = ep.next_op_tag();
    let rank = ep.rank;
    let next = ep.ring_next();
    let prev = ep.ring_prev();
    let (own_off, own_len) = chunk_range(total_len, n, rank);
    debug_assert_eq!(shard.len(), own_len, "shard size mismatch");

    let mut out = vec![0.0f32; total_len];
    out[own_off..own_off + own_len].copy_from_slice(shard);

    // Step s: send chunk (rank - s), receive chunk (rank - s - 1).
    for s in 0..n - 1 {
        let send_idx = (rank + n - s) % n;
        let recv_idx = (rank + n - s - 1) % n;
        let (so, sl) = chunk_range(total_len, n, send_idx);
        ep.send(next, tag0 + s as u64, out[so..so + sl].to_vec());
        let incoming = ep.recv(prev, tag0 + s as u64);
        let (ro, rl) = chunk_range(total_len, n, recv_idx);
        debug_assert_eq!(incoming.len(), rl);
        out[ro..ro + rl].copy_from_slice(&incoming);
    }
    out
}

/// Ring all-reduce = reduce-scatter + all-gather (Figure 1).
pub fn all_reduce(ep: &mut Endpoint, data: &[f32]) -> Vec<f32> {
    let shard = reduce_scatter(ep, data);
    all_gather(ep, &shard, data.len())
}

/// Linear-pipeline broadcast from `root` around the ring.
pub fn broadcast(ep: &mut Endpoint, root: usize, data: Vec<f32>) -> Vec<f32> {
    let n = ep.n;
    if n == 1 {
        return data;
    }
    let tag = ep.next_op_tag();
    // distance from root along the ring
    let dist = (ep.rank + n - root) % n;
    let out = if dist == 0 {
        data
    } else {
        ep.recv(ep.ring_prev(), tag)
    };
    if dist + 1 < n {
        ep.send(ep.ring_next(), tag, out.clone());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::ring_model_seconds;
    use crate::fabric::{self, Topology};
    use crate::util::prop;
    use crate::util::rng::Rng;

    const ALPHA: f64 = 2e-6;
    const BETA: f64 = 1e-9;

    fn flat(n: usize) -> Topology {
        Topology::flat(n, ALPHA, BETA)
    }

    /// rank-dependent deterministic test vector
    fn input(rank: usize, len: usize) -> Vec<f32> {
        (0..len).map(|i| (rank * len + i) as f32 * 0.25).collect()
    }

    fn expected_sum(n: usize, len: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; len];
        for r in 0..n {
            for (o, x) in out.iter_mut().zip(input(r, len)) {
                *o += x;
            }
        }
        out
    }

    #[test]
    fn reduce_scatter_sums_chunks() {
        for n in [2usize, 3, 4, 8] {
            let len = 23;
            let out = fabric::run(n, flat(n), move |ep| {
                reduce_scatter(ep, &input(ep.rank, len))
            });
            let full = expected_sum(n, len);
            for (r, shard) in out.iter().enumerate() {
                let (o, l) = chunk_range(len, n, r);
                assert_eq!(shard.as_slice(), &full[o..o + l], "n={n} r={r}");
            }
        }
    }

    #[test]
    fn all_gather_reassembles() {
        for n in [2usize, 4, 7] {
            let len = 31;
            let out = fabric::run(n, flat(n), move |ep| {
                let (o, l) = chunk_range(len, n, ep.rank);
                let full: Vec<f32> = (0..len).map(|i| i as f32).collect();
                all_gather(ep, &full[o..o + l], len)
            });
            for shard in out {
                assert_eq!(shard, (0..len).map(|i| i as f32).collect::<Vec<_>>());
            }
        }
    }

    #[test]
    fn all_reduce_equals_direct_sum() {
        for n in [2usize, 4, 8] {
            let len = 50;
            let out = fabric::run(n, flat(n), move |ep| {
                all_reduce(ep, &input(ep.rank, len))
            });
            let full = expected_sum(n, len);
            for got in out {
                for (g, e) in got.iter().zip(&full) {
                    assert!((g - e).abs() < 1e-3, "{g} vs {e}");
                }
            }
        }
    }

    #[test]
    fn broadcast_from_any_root() {
        for root in 0..4 {
            let out = fabric::run(4, flat(4), move |ep| {
                let data = if ep.rank == root {
                    vec![3.0, 1.0, 4.0]
                } else {
                    Vec::new()
                };
                broadcast(ep, root, data)
            });
            for got in out {
                assert_eq!(got, vec![3.0, 1.0, 4.0]);
            }
        }
    }

    #[test]
    fn all_reduce_time_matches_alpha_beta_model() {
        // The fabric's logical clocks should realize ≈ 2(N-1)(α + Sβ/N):
        // ring steps serialize on the critical path.
        let n = 8;
        let len = 1 << 18; // 1 MiB payload
        let times = fabric::run_timed(n, flat(n), move |ep| {
            all_reduce(ep, &vec![1.0f32; len]);
        });
        let bytes = (len * 4) as f64;
        let model = ring_model_seconds(2.0, bytes, n, ALPHA, BETA);
        for (_, t) in times {
            let ratio = t / model;
            // ring pipelining and chunk rounding put us within ~25%
            assert!((0.75..1.35).contains(&ratio),
                    "fabric {t} vs model {model} (ratio {ratio})");
        }
    }

    #[test]
    fn property_all_reduce_random_shapes() {
        prop::check(
            0xC011,
            12,
            |rng: &mut Rng, size| {
                let n = rng.range(2, 6);
                let len = rng.range(1, size * 8);
                let seed = rng.next_u64();
                (n, len, seed)
            },
            |&(n, len, seed)| {
                let out = fabric::run(n, flat(n), move |ep| {
                    let mut r = Rng::new(seed + ep.rank as u64);
                    let data: Vec<f32> =
                        (0..len).map(|_| r.normal() as f32).collect();
                    (data.clone(), all_reduce(ep, &data))
                });
                let mut want = vec![0.0f64; len];
                for (data, _) in &out {
                    for (w, x) in want.iter_mut().zip(data) {
                        *w += *x as f64;
                    }
                }
                for (_, got) in &out {
                    for (g, e) in got.iter().zip(&want) {
                        if (*g as f64 - e).abs() > 1e-3 {
                            return Err(format!("{g} != {e}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
