//! In-process device fabric: the hardware substitute (DESIGN.md §2).
//!
//! One OS thread per simulated device. Data really moves between threads
//! (collectives are numerically checked), while *time* is simulated with a
//! per-device logical clock and a link model: a message of `B` bytes sent
//! at sender-time `t` arrives no earlier than `t + α + B·β`, with (α, β)
//! chosen per link by the [`Topology`] (intra- vs inter-node) — exactly the
//! Hockney model the paper's cost formulas assume, so measured fabric time
//! and the analytic model can be compared (they are, in `rust/tests/`).

pub mod topology;

pub use topology::Topology;

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender, channel};
use std::sync::Arc;
use std::thread;

/// Bytes per f32 element on the wire.
pub const WIRE_F32: f64 = 4.0;

/// A message between devices: payload plus the sender's departure time.
struct Msg {
    from: usize,
    tag: u64,
    data: Vec<f32>,
    /// Sender logical time at send.
    depart: f64,
}

/// One device's handle onto the fabric, owned by its worker thread.
pub struct Endpoint {
    pub rank: usize,
    pub n: usize,
    topology: Topology,
    clock: f64,
    tx: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    /// Out-of-order receive buffer keyed by (from, tag).
    pending: HashMap<(usize, u64), (Vec<f32>, f64)>,
    /// Per-collective tag namespace (see [`Endpoint::next_op_tag`]).
    op_seq: u64,
    /// Total payload bytes sent (for bandwidth accounting).
    pub bytes_sent: u64,
}

impl Endpoint {
    /// Current logical time (seconds since iteration start).
    pub fn now(&self) -> f64 {
        self.clock
    }

    /// Advance the local clock by `seconds` of computation.
    pub fn compute(&mut self, seconds: f64) {
        debug_assert!(seconds >= 0.0);
        self.clock += seconds;
    }

    /// Reserve a fresh tag namespace for one collective operation. All
    /// ranks call collectives in the same order, so sequence numbers agree.
    pub fn next_op_tag(&mut self) -> u64 {
        self.op_seq += 1;
        self.op_seq << 20
    }

    /// Send `data` to `to` (non-blocking; the link model is applied on the
    /// receive side using the departure timestamp).
    pub fn send(&mut self, to: usize, tag: u64, data: Vec<f32>) {
        debug_assert!(to != self.rank, "self-send");
        self.bytes_sent += (data.len() as f64 * WIRE_F32) as u64;
        let msg = Msg { from: self.rank, tag, data, depart: self.clock };
        self.tx[to].send(msg).expect("fabric channel closed");
    }

    /// Blocking receive of the message tagged `tag` from `from`; advances
    /// the local clock to the arrival time.
    pub fn recv(&mut self, from: usize, tag: u64) -> Vec<f32> {
        let (data, depart) = loop {
            if let Some(hit) = self.pending.remove(&(from, tag)) {
                break hit;
            }
            let m = self.rx.recv().expect("fabric channel closed");
            // fast path: in SPMD collectives the next message is almost
            // always the one we're waiting for — skip the pending map
            if m.from == from && m.tag == tag {
                break (m.data, m.depart);
            }
            self.pending.insert((m.from, m.tag), (m.data, m.depart));
        };
        let bytes = data.len() as f64 * WIRE_F32;
        let (alpha, beta) = self.topology.link(from, self.rank);
        let arrival = depart + alpha + bytes * beta;
        self.clock = self.clock.max(arrival);
        data
    }

    /// Devices per node in the underlying topology (used by hierarchical
    /// collectives to form intra-node subgroups).
    pub fn topology_devices_per_node(&self) -> usize {
        self.topology.devices_per_node
    }

    /// Ring neighbors (next/prev rank).
    pub fn ring_next(&self) -> usize {
        (self.rank + 1) % self.n
    }

    pub fn ring_prev(&self) -> usize {
        (self.rank + self.n - 1) % self.n
    }
}

/// Spawn `n` device threads, run `f` on each, and return per-rank results
/// paired with each device's final logical clock. Panics propagate.
pub fn run_timed<T, F>(n: usize, topology: Topology, f: F) -> Vec<(T, f64)>
where
    T: Send + 'static,
    F: Fn(&mut Endpoint) -> T + Send + Sync + 'static,
{
    assert!(n > 0);
    let mut to_device: Vec<Sender<Msg>> = Vec::with_capacity(n);
    let mut rxs: Vec<Option<Receiver<Msg>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        to_device.push(tx);
        rxs.push(Some(rx));
    }
    let f = Arc::new(f);
    let mut handles = Vec::with_capacity(n);
    for (rank, rx_slot) in rxs.iter_mut().enumerate() {
        let rx = rx_slot.take().unwrap();
        let tx = to_device.clone();
        let topology = topology.clone();
        let f = f.clone();
        handles.push(thread::spawn(move || {
            let mut ep = Endpoint {
                rank,
                n,
                topology,
                clock: 0.0,
                tx,
                rx,
                pending: HashMap::new(),
                op_seq: 0,
                bytes_sent: 0,
            };
            let out = f(&mut ep);
            (out, ep.clock)
        }));
    }
    handles
        .into_iter()
        .map(|h| h.join().expect("device thread panicked"))
        .collect()
}

/// [`run_timed`] without the clocks.
pub fn run<T, F>(n: usize, topology: Topology, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(&mut Endpoint) -> T + Send + Sync + 'static,
{
    run_timed(n, topology, f).into_iter().map(|(t, _)| t).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat(n: usize) -> Topology {
        Topology::flat(n, 1e-6, 1e-9)
    }

    #[test]
    fn pingpong_moves_data_and_time() {
        let out = run_timed(2, flat(2), |ep| {
            if ep.rank == 0 {
                ep.send(1, 7, vec![1.0, 2.0, 3.0]);
                Vec::new()
            } else {
                ep.recv(0, 7)
            }
        });
        assert_eq!(out[1].0, vec![1.0, 2.0, 3.0]);
        // receiver clock advanced by α + 12B·β
        let expect = 1e-6 + 12.0 * 1e-9;
        assert!((out[1].1 - expect).abs() < 1e-12, "{}", out[1].1);
        assert_eq!(out[0].1, 0.0); // sender: async send, no time
    }

    #[test]
    fn out_of_order_tags_are_buffered() {
        let out = run(2, flat(2), |ep| {
            if ep.rank == 0 {
                ep.send(1, 1, vec![1.0]);
                ep.send(1, 2, vec![2.0]);
                0.0
            } else {
                // receive in reverse order
                let b = ep.recv(0, 2)[0];
                let a = ep.recv(0, 1)[0];
                10.0 * a + b
            }
        });
        assert_eq!(out[1], 12.0);
    }

    #[test]
    fn compute_advances_clock() {
        let out = run_timed(1, flat(1), |ep| {
            ep.compute(0.25);
            ep.compute(0.25);
        });
        assert_eq!(out[0].1, 0.5);
    }

    #[test]
    fn receive_waits_for_late_sender() {
        let out = run_timed(2, flat(2), |ep| {
            if ep.rank == 0 {
                ep.compute(1.0); // busy before sending
                ep.send(1, 3, vec![1.0; 256]);
            } else {
                ep.recv(0, 3);
            }
        });
        // receiver idles until 1.0 + link time
        assert!(out[1].1 >= 1.0);
    }

    #[test]
    fn bytes_sent_accounted() {
        let out = run(2, flat(2), |ep| {
            if ep.rank == 0 {
                ep.send(1, 1, vec![0.0; 100]);
            } else {
                ep.recv(0, 1);
            }
            ep.bytes_sent
        });
        assert_eq!(out[0], 400);
        assert_eq!(out[1], 0);
    }

    #[test]
    fn ring_neighbors() {
        let out = run(4, flat(4), |ep| (ep.ring_next(), ep.ring_prev()));
        assert_eq!(out[0], (1, 3));
        assert_eq!(out[3], (0, 2));
    }
}
