//! Link topology: per-pair (α, β) for the fabric's time model.
//!
//! Two levels, matching the paper's testbeds: devices within a node share
//! the fast link (PCIe/NVLink); devices on different nodes pay the
//! inter-node link (the 100 Gb/s Ethernet of the two-server setup).

use crate::config::Cluster;

/// Two-level cluster topology.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    pub n_devices: usize,
    pub devices_per_node: usize,
    pub alpha_intra: f64,
    pub beta_intra: f64,
    pub alpha_inter: f64,
    pub beta_inter: f64,
}

impl Topology {
    /// Single-level topology: every pair shares (α, β).
    pub fn flat(n: usize, alpha: f64, beta: f64) -> Topology {
        Topology {
            n_devices: n,
            devices_per_node: n,
            alpha_intra: alpha,
            beta_intra: beta,
            alpha_inter: alpha,
            beta_inter: beta,
        }
    }

    /// Build from a [`Cluster`] description.
    pub fn from_cluster(c: &Cluster) -> Topology {
        Topology {
            n_devices: c.n_devices,
            devices_per_node: c.devices_per_node,
            alpha_intra: c.alpha_intra,
            beta_intra: c.beta_intra,
            alpha_inter: c.alpha_inter,
            beta_inter: c.beta_inter,
        }
    }

    /// Node index of a device.
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.devices_per_node
    }

    /// (α, β) of the link between two devices.
    pub fn link(&self, from: usize, to: usize) -> (f64, f64) {
        if self.node_of(from) == self.node_of(to) {
            (self.alpha_intra, self.beta_intra)
        } else {
            (self.alpha_inter, self.beta_inter)
        }
    }

    /// Ranks co-located on `node`.
    pub fn node_members(&self, node: usize) -> Vec<usize> {
        let lo = node * self.devices_per_node;
        let hi = ((node + 1) * self.devices_per_node).min(self.n_devices);
        (lo..hi).collect()
    }

    pub fn n_nodes(&self) -> usize {
        self.n_devices.div_ceil(self.devices_per_node)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_topology_is_uniform() {
        let t = Topology::flat(4, 1e-6, 1e-9);
        assert_eq!(t.link(0, 3), (1e-6, 1e-9));
        assert_eq!(t.n_nodes(), 1);
    }

    #[test]
    fn two_level_links() {
        let t = Topology {
            n_devices: 16,
            devices_per_node: 8,
            alpha_intra: 1e-6,
            beta_intra: 1e-10,
            alpha_inter: 1e-5,
            beta_inter: 1e-8,
        };
        assert_eq!(t.link(0, 7), (1e-6, 1e-10)); // same node
        assert_eq!(t.link(7, 8), (1e-5, 1e-8)); // across nodes
        assert_eq!(t.node_of(7), 0);
        assert_eq!(t.node_of(8), 1);
        assert_eq!(t.n_nodes(), 2);
        assert_eq!(t.node_members(1), (8..16).collect::<Vec<_>>());
    }

    #[test]
    fn from_cluster_copies_links() {
        let c = Cluster::two_server_a100(16.0);
        let t = Topology::from_cluster(&c);
        assert_eq!(t.n_devices, 16);
        assert_eq!(t.devices_per_node, 8);
        assert_eq!(t.link(0, 15), (c.alpha_inter, c.beta_inter));
    }
}
