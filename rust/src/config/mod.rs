//! Configuration: cluster/device information ("Device Information" input in
//! Figure 2) and run settings, loadable from TOML-subset files.

mod parse;

pub use parse::{ParseError, TomlDoc, Value};

/// Gibibytes → bytes.
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Cluster description: parallelism degree, topology, link model, compute.
///
/// The paper's (α, β, γ) model (§3.1): `alpha_*` is per-ring-step latency,
/// `beta_*` transfer seconds per byte; `γ_i` is derived per operator from
/// `flops` (see `cost::profiler`).
#[derive(Debug, Clone, PartialEq)]
pub struct Cluster {
    /// Parallelism degree N (number of devices).
    pub n_devices: usize,
    /// Devices per node: collectives spanning nodes pay the inter-node link.
    pub devices_per_node: usize,
    /// Device memory limit `M_limit` in bytes (the experiments use 8/16 GiB).
    pub mem_limit: f64,
    /// Ring-step latency within a node (seconds).
    pub alpha_intra: f64,
    /// Transfer time per byte within a node (seconds/byte).
    pub beta_intra: f64,
    /// Ring-step latency across nodes (seconds).
    pub alpha_inter: f64,
    /// Transfer time per byte across nodes (seconds/byte).
    pub beta_inter: f64,
    /// Per-device sustained fp32 FLOP/s (calibrated or preset).
    pub flops: f64,
    /// Overlap communication with computation where legal (§3.3: OSDP's
    /// deployment "supports the overlapping between computation and
    /// communication"; the *search* cost model keeps them additive, as the
    /// paper's formulation does).
    pub overlap: bool,
}

impl Cluster {
    /// The paper's laboratorial server: 8× NVIDIA RTX TITAN 24 GB on
    /// PCIe 3.0. Ring bandwidth ≈ 12 GB/s effective, fp32 ≈ 14 TFLOP/s.
    pub fn rtx_titan(n_devices: usize, mem_limit_gib: f64) -> Cluster {
        Cluster {
            n_devices,
            devices_per_node: n_devices,
            mem_limit: mem_limit_gib * GIB,
            alpha_intra: 10e-6,
            beta_intra: 1.0 / 12e9,
            alpha_inter: 10e-6,
            beta_inter: 1.0 / 12e9,
            flops: 14e12,
            overlap: true,
        }
    }

    /// The paper's two cloud servers with A100 GPUs, 100 Gb/s between the
    /// servers (Figure 6): NVLink intra-node, 12.5 GB/s inter-node.
    pub fn two_server_a100(mem_limit_gib: f64) -> Cluster {
        Cluster {
            n_devices: 16,
            devices_per_node: 8,
            mem_limit: mem_limit_gib * GIB,
            alpha_intra: 5e-6,
            beta_intra: 1.0 / 200e9,
            alpha_inter: 30e-6,
            beta_inter: 1.0 / 12.5e9,
            flops: 19.5e12,
            overlap: true,
        }
    }

    /// Number of nodes (ceil division).
    pub fn n_nodes(&self) -> usize {
        self.n_devices.div_ceil(self.devices_per_node)
    }

    /// Effective size of one node's device group — `devices_per_node`
    /// clamped to the cluster (a "node" never exceeds the machine). The
    /// single definition every node-scoped quantity derives its divisor
    /// from (cost, memory, sim).
    pub fn node_group_size(&self) -> usize {
        self.devices_per_node.min(self.n_devices)
    }

    /// Whether a collective over all N devices crosses a node boundary.
    pub fn crosses_nodes(&self) -> bool {
        self.n_devices > self.devices_per_node
    }

    /// Effective per-ring-step (α, β) for a collective spanning all devices:
    /// a ring across nodes is bottlenecked by its slowest link.
    pub fn ring_link(&self) -> (f64, f64) {
        if self.crosses_nodes() {
            (self.alpha_inter, self.beta_inter)
        } else {
            (self.alpha_intra, self.beta_intra)
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_devices == 0 {
            return Err("n_devices must be > 0".into());
        }
        if self.devices_per_node == 0 {
            return Err("devices_per_node must be > 0".into());
        }
        // Non-uniform node layouts (a trailing partial node) would make
        // the hierarchical collectives silently fall back to the flat
        // ring and desynchronize the cost model from the fabric — reject
        // them up front rather than mis-plan quietly.
        if self.n_devices > self.devices_per_node
            && self.n_devices % self.devices_per_node != 0
        {
            return Err(format!(
                "non-uniform node layout: {} devices cannot be split into \
                 equal nodes of {} (hierarchical schedules and node-scoped \
                 sharding require uniform nodes)",
                self.n_devices, self.devices_per_node
            ));
        }
        // `!(x > 0.0)` instead of `x <= 0.0`: NaN fails every comparison,
        // so the old spelling silently accepted NaN limits — which then
        // defeat every `peak > limit` prune downstream (NaN comparisons
        // are false, so *everything* looks feasible). Found auditing the
        // plan-service query path.
        if !(self.mem_limit > 0.0) || !self.mem_limit.is_finite() {
            return Err("mem_limit must be finite and > 0".into());
        }
        if !(self.flops > 0.0) || !self.flops.is_finite() {
            return Err("flops must be finite and > 0".into());
        }
        for (name, v) in [
            ("alpha_intra", self.alpha_intra),
            ("beta_intra", self.beta_intra),
            ("alpha_inter", self.alpha_inter),
            ("beta_inter", self.beta_inter),
        ] {
            if v < 0.0 || !v.is_finite() {
                return Err(format!("{name} must be finite and >= 0"));
            }
        }
        Ok(())
    }
}

/// Search-engine settings (Algorithm 1 knobs).
#[derive(Debug, Clone, PartialEq)]
pub struct SearchConfig {
    /// Maximum batch size the Scheduler will try (safety bound; the paper
    /// stops when nothing fits).
    pub max_batch: usize,
    /// Candidate slice granularities for operator splitting (0 = off).
    pub granularities: Vec<usize>,
    /// Enable checkpointing in the cost model (Figure 9).
    pub checkpointing: bool,
    /// Plan on the paper's coarse 2-ops/layer granularity instead of the
    /// fine-grained graph.
    pub paper_granularity: bool,
    /// Offer node-local sharding scopes (MiCS/HSDP-style) alongside the
    /// paper's global scope on clusters that cross node boundaries; menus
    /// grow by at most 2× per operator. Off restricts the search to the
    /// paper's `{DP, ZDP-over-N}` space.
    pub hybrid_scopes: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            max_batch: 1024,
            granularities: vec![0, 2, 4, 8, 16],
            checkpointing: false,
            paper_granularity: false,
            hybrid_scopes: true,
        }
    }
}

/// A full run configuration, parsed from a TOML-subset file:
///
/// ```toml
/// [cluster]
/// preset = "rtx_titan"       # or "two_server_a100" / "custom"
/// n_devices = 8
/// mem_limit_gib = 8.0
///
/// [search]
/// max_batch = 256
/// granularities = [0, 2, 4, 8]
/// checkpointing = false
/// ```
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub cluster: Cluster,
    pub search: SearchConfig,
}

impl RunConfig {
    pub fn from_str(text: &str) -> Result<RunConfig, String> {
        let doc = TomlDoc::parse(text).map_err(|e| e.to_string())?;

        let n = doc.get("cluster", "n_devices")
            .and_then(Value::as_usize).unwrap_or(8);
        let mem = doc.get("cluster", "mem_limit_gib")
            .and_then(Value::as_f64).unwrap_or(8.0);
        let preset = doc.get("cluster", "preset")
            .and_then(Value::as_str).unwrap_or("rtx_titan");
        let mut cluster = match preset {
            "rtx_titan" => Cluster::rtx_titan(n, mem),
            "two_server_a100" => Cluster::two_server_a100(mem),
            "custom" => Cluster::rtx_titan(n, mem), // base, overridden below
            other => return Err(format!("unknown cluster preset '{other}'")),
        };
        // optional field-level overrides
        #[allow(unused_mut)]
        let mut override_f64 = |key: &str, field: &mut f64| {
            if let Some(v) = doc.get("cluster", key).and_then(Value::as_f64) {
                *field = v;
            }
        };
        override_f64("alpha_intra", &mut cluster.alpha_intra);
        override_f64("beta_intra", &mut cluster.beta_intra);
        override_f64("alpha_inter", &mut cluster.alpha_inter);
        override_f64("beta_inter", &mut cluster.beta_inter);
        override_f64("flops", &mut cluster.flops);
        if let Some(dpn) = doc.get("cluster", "devices_per_node")
            .and_then(Value::as_usize)
        {
            cluster.devices_per_node = dpn;
        }
        cluster.validate()?;

        let mut search = SearchConfig::default();
        if let Some(mb) = doc.get("search", "max_batch").and_then(Value::as_usize) {
            search.max_batch = mb;
        }
        if let Some(g) = doc.get("search", "granularities").and_then(Value::as_arr) {
            search.granularities =
                g.iter().filter_map(Value::as_usize).collect();
        }
        if let Some(c) = doc.get("search", "checkpointing").and_then(Value::as_bool) {
            search.checkpointing = c;
        }
        if let Some(p) = doc.get("search", "paper_granularity")
            .and_then(Value::as_bool)
        {
            search.paper_granularity = p;
        }
        if let Some(h) = doc.get("search", "hybrid_scopes")
            .and_then(Value::as_bool)
        {
            search.hybrid_scopes = h;
        }
        Ok(RunConfig { cluster, search })
    }

    pub fn from_file(path: &str) -> Result<RunConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))?;
        RunConfig::from_str(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        assert!(Cluster::rtx_titan(8, 8.0).validate().is_ok());
        assert!(Cluster::two_server_a100(16.0).validate().is_ok());
    }

    #[test]
    fn two_server_crosses_nodes() {
        let c = Cluster::two_server_a100(16.0);
        assert_eq!(c.n_nodes(), 2);
        assert!(c.crosses_nodes());
        assert_eq!(c.ring_link(), (c.alpha_inter, c.beta_inter));
        let single = Cluster::rtx_titan(8, 8.0);
        assert!(!single.crosses_nodes());
        assert_eq!(single.ring_link(), (single.alpha_intra, single.beta_intra));
    }

    #[test]
    fn run_config_parses_full() {
        let cfg = RunConfig::from_str(
            r#"
            [cluster]
            preset = "rtx_titan"
            n_devices = 4
            mem_limit_gib = 16.0
            flops = 1.0e12

            [search]
            max_batch = 64
            granularities = [0, 4]
            checkpointing = true
            "#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.n_devices, 4);
        assert_eq!(cfg.cluster.mem_limit, 16.0 * GIB);
        assert_eq!(cfg.cluster.flops, 1.0e12);
        assert_eq!(cfg.search.max_batch, 64);
        assert_eq!(cfg.search.granularities, vec![0, 4]);
        assert!(cfg.search.checkpointing);
    }

    #[test]
    fn run_config_defaults() {
        let cfg = RunConfig::from_str("").unwrap();
        assert_eq!(cfg.cluster.n_devices, 8);
        assert_eq!(cfg.search.max_batch, 1024);
    }

    #[test]
    fn bad_preset_rejected() {
        assert!(RunConfig::from_str("[cluster]\npreset = \"tpu\"").is_err());
    }

    #[test]
    fn invalid_cluster_rejected() {
        let c = Cluster { n_devices: 0, ..Cluster::rtx_titan(8, 8.0) };
        assert!(c.validate().is_err());
    }

    #[test]
    fn non_finite_limits_rejected() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 0.0, -1.0] {
            let c = Cluster { mem_limit: bad, ..Cluster::rtx_titan(8, 8.0) };
            assert!(c.validate().is_err(), "mem_limit={bad} accepted");
            let c = Cluster { flops: bad, ..Cluster::rtx_titan(8, 8.0) };
            assert!(c.validate().is_err(), "flops={bad} accepted");
        }
    }

    #[test]
    fn non_uniform_node_layout_rejected() {
        // 10 devices over nodes of 4 leaves a partial node: the
        // hierarchical schedules would silently fall back — reject.
        let c = Cluster {
            n_devices: 10,
            devices_per_node: 4,
            ..Cluster::rtx_titan(8, 8.0)
        };
        let err = c.validate().unwrap_err();
        assert!(err.contains("non-uniform"), "{err}");
        // uniform multi-node and single-node layouts stay valid, and so
        // does devices_per_node exceeding n_devices (one partial node =
        // one node)
        for (n, dpn) in [(16usize, 8usize), (8, 8), (4, 8), (12, 4)] {
            let ok = Cluster {
                n_devices: n,
                devices_per_node: dpn,
                ..Cluster::rtx_titan(8, 8.0)
            };
            assert!(ok.validate().is_ok(), "n={n} dpn={dpn}");
        }
        // ...and the config loader surfaces the validation error
        assert!(RunConfig::from_str(
            "[cluster]\nn_devices = 10\ndevices_per_node = 4"
        )
        .is_err());
    }

    #[test]
    fn hybrid_scopes_knob_parses_and_defaults_on() {
        let def = RunConfig::from_str("").unwrap();
        assert!(def.search.hybrid_scopes, "scopes default on");
        let off = RunConfig::from_str("[search]\nhybrid_scopes = false")
            .unwrap();
        assert!(!off.search.hybrid_scopes);
    }
}
