//! TOML-subset parser for run configuration files.
//!
//! Supported grammar (all the project's configs need):
//!   - `[section]` headers
//!   - `key = value` with value ∈ string ("..."), float/int, bool,
//!     flat arrays `[v, v, ...]`
//!   - `#` comments, blank lines
//!
//! Not supported (rejected loudly): nested tables, inline tables, dotted
//! keys, multi-line strings, datetime.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar or flat array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 { Some(n as usize) } else { None }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse failure with line number.
#[derive(Debug, Clone)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// A parsed document: `section -> key -> value`. Keys before any header
/// land in the "" section.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, ParseError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or(ParseError {
                    line: lineno,
                    msg: "unterminated section header".into(),
                })?;
                if name.contains('[') || name.contains('.') {
                    return Err(ParseError {
                        line: lineno,
                        msg: format!("unsupported table syntax '{name}'"),
                    });
                }
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = line.find('=').ok_or(ParseError {
                line: lineno,
                msg: "expected 'key = value'".into(),
            })?;
            let key = line[..eq].trim();
            if key.is_empty() || key.contains('.') {
                return Err(ParseError {
                    line: lineno,
                    msg: format!("bad key '{key}'"),
                });
            }
            let value = parse_value(line[eq + 1..].trim()).map_err(|msg| {
                ParseError { line: lineno, msg }
            })?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key.to_string(), value);
        }
        Ok(doc)
    }

    /// Look up `[section] key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn sections(&self) -> impl Iterator<Item = &String> {
        self.sections.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let mut out = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue; // trailing comma
            }
            out.push(parse_value(part)?);
        }
        return Ok(Value::Arr(out));
    }
    s.replace('_', "")
        .parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            top = 1
            [a]
            s = "hello"   # comment
            n = -2.5e3
            b = true
            arr = [1, 2, 3,]
            [b]
            big = 1_000_000
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top"), Some(&Value::Num(1.0)));
        assert_eq!(doc.get("a", "s").unwrap().as_str(), Some("hello"));
        assert_eq!(doc.get("a", "n").unwrap().as_f64(), Some(-2500.0));
        assert_eq!(doc.get("a", "b").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("a", "arr").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(doc.get("b", "big").unwrap().as_usize(), Some(1_000_000));
    }

    #[test]
    fn hash_in_string_not_comment() {
        let doc = TomlDoc::parse(r##"k = "a#b""##).unwrap();
        assert_eq!(doc.get("", "k").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_nested_tables() {
        assert!(TomlDoc::parse("[a.b]\nk = 1").is_err());
        assert!(TomlDoc::parse("a.b = 1").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[open").is_err());
        assert!(TomlDoc::parse("novalue =").is_err());
        assert!(TomlDoc::parse("just a line").is_err());
        assert!(TomlDoc::parse("k = [1, 2").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
    }

    #[test]
    fn error_reports_line() {
        let err = TomlDoc::parse("good = 1\nbad line").unwrap_err();
        assert_eq!(err.line, 2);
    }
}
