//! Result aggregation: figure-style throughput tables and the paper's
//! headline statistics (max/average speedups between strategies).

use crate::parallel::Estimate;
use crate::util::table::Table;

/// One figure cell: a (model setting, strategy) throughput measurement.
#[derive(Debug, Clone)]
pub struct Cell {
    pub family: String,
    pub setting: String,
    pub strategy: String,
    pub estimate: Estimate,
}

/// A full figure's worth of cells.
#[derive(Debug, Clone, Default)]
pub struct FigureData {
    pub title: String,
    pub cells: Vec<Cell>,
}

impl FigureData {
    pub fn new(title: &str) -> FigureData {
        FigureData { title: title.into(), cells: Vec::new() }
    }

    pub fn push(&mut self, family: &str, setting: &str, e: Estimate) {
        self.cells.push(Cell {
            family: family.into(),
            setting: setting.into(),
            strategy: e.strategy.clone(),
            estimate: e,
        });
    }

    fn settings(&self) -> Vec<(String, String)> {
        let mut out: Vec<(String, String)> = Vec::new();
        for c in &self.cells {
            let key = (c.family.clone(), c.setting.clone());
            if !out.contains(&key) {
                out.push(key);
            }
        }
        out
    }

    fn strategies(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for c in &self.cells {
            if !out.contains(&c.strategy) {
                out.push(c.strategy.clone());
            }
        }
        out
    }

    pub fn get(&self, family: &str, setting: &str, strategy: &str)
               -> Option<&Estimate> {
        self.cells
            .iter()
            .find(|c| {
                c.family == family && c.setting == setting
                    && c.strategy == strategy
            })
            .map(|c| &c.estimate)
    }

    /// Render the figure as a table: rows = settings, cols = strategies,
    /// cells = samples/s ("OOM"/"N/A" when infeasible — the paper's bar
    /// annotations).
    pub fn render(&self) -> String {
        let strategies = self.strategies();
        let mut header = vec!["model".to_string(), "setting".to_string()];
        header.extend(strategies.iter().cloned());
        let mut t = Table::new(header);
        for (family, setting) in self.settings() {
            let mut row = vec![family.clone(), setting.clone()];
            for s in &strategies {
                row.push(match self.get(&family, &setting, s) {
                    Some(e) if e.feasible => format!("{:.1}", e.throughput),
                    Some(e) => e
                        .reason
                        .clone()
                        .unwrap_or_else(|| "OOM".into())
                        .split(' ')
                        .next()
                        .unwrap()
                        .to_string(),
                    None => "-".into(),
                });
            }
            t.row(row);
        }
        format!("== {} ==\n{}", self.title, t.render())
    }

    pub fn to_csv(&self) -> String {
        let mut t = Table::new(vec![
            "family", "setting", "strategy", "feasible", "throughput",
            "iter_time", "peak_mem", "global_batch", "detail",
        ]);
        for c in &self.cells {
            let e = &c.estimate;
            t.row(vec![
                c.family.clone(),
                c.setting.clone(),
                c.strategy.clone(),
                e.feasible.to_string(),
                format!("{:.3}", e.throughput),
                format!("{:.6}", e.iter_time),
                format!("{:.0}", e.peak_mem),
                e.global_batch.to_string(),
                e.detail.clone(),
            ]);
        }
        t.to_csv()
    }
}

/// Speedup statistics of `ours` over `baseline` across matching settings
/// (only where both are feasible) — the paper's "maximum of X% and an
/// average of Y% speedup" numbers.
#[derive(Debug, Clone, PartialEq)]
pub struct Speedup {
    pub max: f64,
    pub avg: f64,
    pub n: usize,
}

pub fn speedup(fig: &FigureData, ours: &str, baseline: &str) -> Option<Speedup> {
    let mut ratios = Vec::new();
    for (family, setting) in fig.settings() {
        let a = fig.get(&family, &setting, ours);
        let b = fig.get(&family, &setting, baseline);
        if let (Some(a), Some(b)) = (a, b) {
            if a.feasible && b.feasible && b.throughput > 0.0 {
                ratios.push(a.throughput / b.throughput);
            }
        }
    }
    if ratios.is_empty() {
        return None;
    }
    let max = ratios.iter().cloned().fold(f64::MIN, f64::max);
    let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
    Some(Speedup { max, avg, n: ratios.len() })
}

/// Best-baseline comparison: OSDP vs the best feasible non-OSDP strategy
/// per setting (the paper's "outperforms the other pure strategies by up
/// to …").
pub fn speedup_vs_best(fig: &FigureData, ours: &str, exclude: &[&str])
                       -> Option<Speedup> {
    let mut ratios = Vec::new();
    for (family, setting) in fig.settings() {
        let our = match fig.get(&family, &setting, ours) {
            Some(e) if e.feasible => e.throughput,
            _ => continue,
        };
        let best_other = fig
            .cells
            .iter()
            .filter(|c| {
                c.family == family
                    && c.setting == setting
                    && c.strategy != ours
                    && !exclude.contains(&c.strategy.as_str())
                    && c.estimate.feasible
            })
            .map(|c| c.estimate.throughput)
            .fold(0.0f64, f64::max);
        if best_other > 0.0 {
            ratios.push(our / best_other);
        }
    }
    if ratios.is_empty() {
        return None;
    }
    Some(Speedup {
        max: ratios.iter().cloned().fold(f64::MIN, f64::max),
        avg: ratios.iter().sum::<f64>() / ratios.len() as f64,
        n: ratios.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est(strategy: &str, tp: f64) -> Estimate {
        Estimate {
            strategy: strategy.into(),
            feasible: tp > 0.0,
            reason: if tp > 0.0 { None } else { Some("OOM".into()) },
            global_batch: 8,
            iter_time: 1.0,
            throughput: tp,
            peak_mem: 1.0,
            detail: String::new(),
        }
    }

    fn fig() -> FigureData {
        let mut f = FigureData::new("test");
        f.push("N&D", "48L", est("DP", 100.0));
        f.push("N&D", "48L", est("FSDP", 80.0));
        f.push("N&D", "48L", est("OSDP", 120.0));
        f.push("N&D", "96L", est("DP", 0.0)); // OOM
        f.push("N&D", "96L", est("FSDP", 50.0));
        f.push("N&D", "96L", est("OSDP", 60.0));
        f
    }

    #[test]
    fn speedup_over_named_baseline() {
        let s = speedup(&fig(), "OSDP", "FSDP").unwrap();
        assert_eq!(s.n, 2);
        assert!((s.max - 1.5).abs() < 1e-12);
        assert!((s.avg - (1.5 + 1.2) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn speedup_skips_infeasible_pairs() {
        let s = speedup(&fig(), "OSDP", "DP").unwrap();
        assert_eq!(s.n, 1); // 96L DP is OOM
        assert!((s.max - 1.2).abs() < 1e-12);
    }

    #[test]
    fn speedup_vs_best_takes_per_setting_max() {
        let s = speedup_vs_best(&fig(), "OSDP", &[]).unwrap();
        // 48L: 120/100; 96L: 60/50
        assert!((s.max - 1.2).abs() < 1e-12);
        assert_eq!(s.n, 2);
    }

    #[test]
    fn render_marks_oom() {
        let r = fig().render();
        assert!(r.contains("OOM"), "{r}");
        assert!(r.contains("120.0"));
    }

    #[test]
    fn csv_round_trips_rows() {
        let c = fig().to_csv();
        assert_eq!(c.lines().count(), 7); // header + 6 cells
    }
}
