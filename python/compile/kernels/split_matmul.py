"""Operator-splitting matmul as a Pallas kernel (OSDP Figure 4 on TPU terms).

The paper splits a huge ``x @ w`` by partitioning the last dim of ``x`` and
the first dim of ``w`` into ``g`` slices, computing slice products
sequentially, and summing — so the peak memory of the gathered weight drops
from ``size(w)`` to ``size(w)/g``.

On TPU/Pallas the same schedule is a K-sliced matmul: ``grid=(g,)`` walks the
contraction dimension, the BlockSpec index map streams one ``(K/g, N)`` slice
of ``w`` (and one ``(M, K/g)`` slice of ``x``) HBM→VMEM per step, and the
output ref doubles as the resident accumulator.  Peak on-chip footprint is
``M*K/g + K/g*N + M*N`` elements instead of ``M*K + K*N + M*N``.

``matmul_tiled`` generalizes to a 3-D grid (M, N, K tiles) — the shape a real
MXU-targeted kernel would use; the K axis remains the sequential
accumulation axis (``dimension_semantics`` would mark m,n "parallel" and k
"arbitrary" on real hardware).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _split_kernel(x_ref, w_ref, o_ref):
    """One slice step: accumulate x_slice @ w_slice into the output ref."""
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("granularity",))
def split_matmul(x: jax.Array, w: jax.Array, granularity: int = 4) -> jax.Array:
    """``x @ w`` with the contraction dim processed in ``granularity`` slices.

    Args:
      x: ``(M, K)`` activation.
      w: ``(K, N)`` weight (the operator being split).
      granularity: number of sequential slices (paper's slice granularity,
        default 4 as in §4.1). Must divide ``K``.

    Returns:
      ``(M, N)`` product, numerically equal to ``x @ w`` (fp32 accumulation).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    if granularity <= 1:
        granularity = 1
    assert k % granularity == 0, (
        f"slice granularity {granularity} must divide K={k}"
    )
    ks = k // granularity
    return pl.pallas_call(
        _split_kernel,
        grid=(granularity,),
        in_specs=[
            pl.BlockSpec((m, ks), lambda i: (0, i)),
            pl.BlockSpec((ks, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w)


def _tiled_kernel(x_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_tiled(
    x: jax.Array, w: jax.Array, bm: int = 128, bn: int = 128, bk: int = 128
) -> jax.Array:
    """MXU-style 3-D tiled matmul; K axis is the sequential accumulator axis.

    Block sizes are clamped to the problem size; each must divide its dim.
    VMEM footprint per step is ``(bm*bk + bk*bn + bm*bn) * itemsize`` bytes —
    the quantity DESIGN.md §Perf budgets against the 16 MiB VMEM bound.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"block ({bm},{bn},{bk}) must divide problem ({m},{n},{k})"
    )
    return pl.pallas_call(
        _tiled_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w)


def vmem_footprint_bytes(m: int, n: int, k: int, granularity: int,
                         itemsize: int = 4) -> int:
    """Analytical peak on-chip footprint of ``split_matmul`` (DESIGN §Perf)."""
    g = max(granularity, 1)
    ks = k // g
    return (m * ks + ks * n + m * n) * itemsize
