"""Tiled causal self-attention as a Pallas kernel.

The attention score matrix ``(S, S)`` is the other "gigantic tensor" the
paper's §3.3 worries about (MatMul outputs): for long sequences it dominates
activation memory.  The streaming schedule below keeps only one
``(block_q, S)`` stripe of scores resident — the same peak-memory idea as
operator splitting, applied to the attention operator.

The kernel computes a full row-block of scores against all keys (one softmax
per row — numerically exact, no online rescaling needed because S fits the
lane dim at our scales), applies the causal mask, and multiplies by V.
Grid walks query blocks; heads/batch are vmapped outside.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, block_q: int,
                 causal: bool):
    qi = pl.program_id(0)
    q = q_ref[...]  # (block_q, d)
    k = k_ref[...]  # (S, d)
    v = v_ref[...]  # (S, d)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        s = k.shape[0]
        row = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
        col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        scores = jnp.where(row >= col, scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    probs = jnp.exp(scores)
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(probs.astype(v.dtype), v,
                         preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q"))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, block_q: int = 64) -> jax.Array:
    """Single-head scaled-dot-product attention, query-block streamed.

    Args:
      q, k, v: ``(S, d)`` arrays (batch/heads vmapped by the caller).
      causal: apply the autoregressive mask.
      block_q: query rows resident per grid step (peak score stripe is
        ``block_q * S`` instead of ``S * S``).
    """
    s, d = q.shape
    block_q = min(block_q, s)
    assert s % block_q == 0, f"block_q {block_q} must divide S={s}"
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_attn_kernel, scale=scale, block_q=block_q,
                             causal=causal)
    return pl.pallas_call(
        kern,
        grid=(s // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), q.dtype),
        interpret=True,
    )(q, k, v)


def attention_mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, block_q: int = 64) -> jax.Array:
    """Multi-head wrapper: ``(H, S, d)`` → ``(H, S, d)`` via vmap."""
    fn = functools.partial(attention, causal=causal, block_q=block_q)
    return jax.vmap(fn)(q, k, v)
