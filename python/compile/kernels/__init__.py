# L1: Pallas kernels for OSDP's compute hot-spots.
#
# The paper's operator-splitting insight (Figure 4: slice a huge MatMul,
# process slices sequentially, sum results) is expressed here as K-sliced
# Pallas matmul kernels: only one slice of the weight lives in on-chip
# memory (VMEM) at a time while the accumulator stays resident.
#
# All kernels run with interpret=True — the CPU PJRT plugin cannot execute
# Mosaic custom-calls (see DESIGN.md §Hardware-Adaptation).
from .split_matmul import split_matmul, matmul_tiled
from .attention import attention
from .layernorm import layernorm

__all__ = ["split_matmul", "matmul_tiled", "attention", "layernorm"]
