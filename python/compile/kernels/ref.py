"""Pure-jnp oracles for the Pallas kernels (the build-time correctness bar).

Every kernel in this package must match its oracle to fp32 tolerance under
the hypothesis sweeps in python/tests/test_kernels.py before artifacts are
considered valid.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain ``x @ w`` with fp32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def split_matmul_ref(x: jax.Array, w: jax.Array, granularity: int) -> jax.Array:
    """Literal Figure-4 semantics: slice, sequential products, sum.

    Kept separate from ``matmul_ref`` so tests can show the paper's
    slice-and-sum definition is itself equivalent to the plain matmul.
    """
    g = max(granularity, 1)
    k = x.shape[-1]
    assert k % g == 0
    ks = k // g
    out = jnp.zeros((x.shape[0], w.shape[1]), dtype=jnp.float32)
    for i in range(g):
        xs = x[:, i * ks:(i + 1) * ks]
        ws = w[i * ks:(i + 1) * ks, :]
        out = out + jnp.dot(xs, ws, preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """Dense single-head SDPA oracle, ``(S, d)`` inputs."""
    s, d = q.shape
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.dot(probs.astype(v.dtype), v,
                   preferred_element_type=jnp.float32).astype(q.dtype)


def layernorm_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array, *,
                  eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta).astype(x.dtype)
