"""Row-blocked LayerNorm as a Pallas kernel.

LayerNorm is memory-bound; the win is the HBM→VMEM streaming schedule
(one row-block resident at a time), not FLOPs.  Included because the GPT
operator graph in rust sizes LN operators separately (they are the cheap
ops OSDP happily leaves in DP mode).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps"))
def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array, *,
              eps: float = 1e-5, block_rows: int = 128) -> jax.Array:
    """LayerNorm over the last dim of ``(R, H)`` with row-block streaming."""
    r, h = x.shape
    block_rows = min(block_rows, r)
    assert r % block_rows == 0, f"block_rows {block_rows} must divide R={r}"
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, h), x.dtype),
        interpret=True,
    )(x, gamma, beta)
