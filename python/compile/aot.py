"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO *text* artifacts for rust (L3).

Run once via ``make artifacts``; rust loads the results through
``HloModuleProto::from_text_file`` and never touches python again.

Interchange format is HLO **text**, not serialized HloModuleProto: jax >=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published ``xla`` 0.1.6 crate links) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts per model config ``c`` with packed length ``P`` (padded to ``PAD``):

  {c}_fwd_loss.hlo.txt    (params[P], tokens[b,s+1])       -> (loss,)
  {c}_grad_step.hlo.txt   (params[P], tokens[b,s+1])       -> (loss, grads[P])
  {c}_adam_p{n}.hlo.txt   (p,g,m,v [P/n], step[])          -> (p',m',v')
  {c}_init.hlo.txt        (seed[])                         -> (params[P],)

plus shared calibration / integration artifacts:

  calib_matmul.hlo.txt    (x[512,512], w[512,512])         -> (y,)
  split_demo_g{g}.hlo.txt (x[256,1024], w[1024,1024])      -> (y,)   g in 1,2,4,8

``manifest.json`` records every artifact's shapes plus the packed-parameter
layout table so the rust side is fully self-describing.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import split_matmul

# Parallelism degrees the rust runtime may use; PAD = lcm so shards are even.
SHARD_DEGREES = [1, 2, 4, 8]
PAD = 8

# Per-worker microbatch each config's artifacts are lowered for.
BATCH_PER_WORKER = {"tiny": 4, "e2e": 4, "gpt100m": 2}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True: rust
    unwraps with to_tuple{1,N})."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(out_dir: str, name: str, text: str, manifest_files: Dict[str, Any],
           **meta) -> None:
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    manifest_files[name] = {"bytes": len(text), **meta}
    print(f"  wrote {name}  ({len(text) / 1e6:.2f} MB)")


def lower_config(cfg: M.GPTConfig, out_dir: str,
                 manifest: Dict[str, Any]) -> None:
    b = BATCH_PER_WORKER[cfg.name]
    p_len = M.packed_len(cfg, pad_to=PAD)
    tok_spec = jax.ShapeDtypeStruct((b, cfg.seq + 1), jnp.int32)
    par_spec = jax.ShapeDtypeStruct((p_len,), jnp.float32)
    files = manifest["files"]
    print(f"config {cfg.name}: P={p_len} ({cfg.param_count()} raw params), "
          f"batch/worker={b}")

    # -- fwd_loss -----------------------------------------------------------
    def fwd_loss(params, tokens):
        return (M.loss_fn(params, tokens, cfg),)

    _write(out_dir, f"{cfg.name}_fwd_loss.hlo.txt",
           to_hlo_text(jax.jit(fwd_loss).lower(par_spec, tok_spec)),
           files, config=cfg.name, role="fwd_loss",
           inputs=[["params", [p_len], "f32"],
                   ["tokens", [b, cfg.seq + 1], "i32"]],
           outputs=[["loss", [], "f32"]])

    # -- grad_step ----------------------------------------------------------
    def gstep(params, tokens):
        return M.grad_step(params, tokens, cfg)

    _write(out_dir, f"{cfg.name}_grad_step.hlo.txt",
           to_hlo_text(jax.jit(gstep).lower(par_spec, tok_spec)),
           files, config=cfg.name, role="grad_step",
           inputs=[["params", [p_len], "f32"],
                   ["tokens", [b, cfg.seq + 1], "i32"]],
           outputs=[["loss", [], "f32"], ["grads", [p_len], "f32"]])

    # -- adam on full vector + every shard size -----------------------------
    for n in SHARD_DEGREES:
        size = p_len // n
        sl = jax.ShapeDtypeStruct((size,), jnp.float32)
        st = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = jax.jit(M.adam_update).lower(sl, sl, sl, sl, st)
        _write(out_dir, f"{cfg.name}_adam_p{n}.hlo.txt",
               to_hlo_text(lowered), files, config=cfg.name, role="adam",
               shard_degree=n,
               inputs=[["p", [size], "f32"], ["g", [size], "f32"],
                       ["m", [size], "f32"], ["v", [size], "f32"],
                       ["step", [], "i32"]],
               outputs=[["p", [size], "f32"], ["m", [size], "f32"],
                        ["v", [size], "f32"]])

    # -- init: seeded parameter vector so all workers agree without comms ---
    def init(seed):
        params = M.init_params(jax.random.PRNGKey(seed), cfg)
        return (M.pack(params, cfg, pad_to=PAD),)

    _write(out_dir, f"{cfg.name}_init.hlo.txt",
           to_hlo_text(jax.jit(init).lower(
               jax.ShapeDtypeStruct((), jnp.int32))),
           files, config=cfg.name, role="init",
           inputs=[["seed", [], "i32"]],
           outputs=[["params", [p_len], "f32"]])

    manifest["configs"][cfg.name] = {
        "vocab": cfg.vocab, "seq": cfg.seq, "layers": cfg.layers,
        "hidden": cfg.hidden, "heads": cfg.heads,
        "slice_granularity": cfg.slice_granularity,
        "param_count": cfg.param_count(),
        "packed_len": p_len, "pad": PAD,
        "batch_per_worker": b,
        "shard_degrees": SHARD_DEGREES,
        "adam": {"lr": 3e-4, "b1": 0.9, "b2": 0.999, "eps": 1e-8},
        "layout": M.layout(cfg),
    }


def lower_shared(out_dir: str, manifest: Dict[str, Any]) -> None:
    files = manifest["files"]

    # Calibration matmul: rust times this to estimate device FLOP/s (gamma).
    def calib(x, w):
        return (jnp.dot(x, w, preferred_element_type=jnp.float32),)

    s = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    _write(out_dir, "calib_matmul.hlo.txt",
           to_hlo_text(jax.jit(calib).lower(s, s)), files, role="calib",
           inputs=[["x", [512, 512], "f32"], ["w", [512, 512], "f32"]],
           outputs=[["y", [512, 512], "f32"]], flops=2 * 512 ** 3)

    # Operator-splitting demo kernels: same matmul at granularities 1..8,
    # proving the Pallas schedule survives the full AOT->rust path.
    xs = jax.ShapeDtypeStruct((256, 1024), jnp.float32)
    ws = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    for g in [1, 2, 4, 8]:
        fn = functools.partial(lambda x, w, g: (split_matmul(x, w, g),), g=g)
        _write(out_dir, f"split_demo_g{g}.hlo.txt",
               to_hlo_text(jax.jit(fn).lower(xs, ws)), files,
               role="split_demo", granularity=g,
               inputs=[["x", [256, 1024], "f32"], ["w", [1024, 1024], "f32"]],
               outputs=[["y", [256, 1024], "f32"]])


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--configs", default="tiny,e2e",
                    help="comma-separated model configs to lower "
                         f"(available: {','.join(M.CONFIGS)})")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest: Dict[str, Any] = {"version": 1, "configs": {}, "files": {}}
    lower_shared(args.out, manifest)
    for name in args.configs.split(","):
        lower_config(M.CONFIGS[name.strip()], args.out, manifest)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['files'])} artifacts")


if __name__ == "__main__":
    main()
