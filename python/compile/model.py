"""L2: GPT forward/backward/optimizer in JAX, built on the L1 Pallas kernels.

This module is the *compile-path* model definition: ``aot.py`` lowers the
functions here to HLO text once, and the rust coordinator executes them on
the PJRT CPU client forever after.  Python never runs on the training path.

Design choices that matter to the rust side:

* **Packed parameters.**  All parameters (and Adam moments) travel as a
  single 1-D fp32 vector, zero-padded to a multiple of the parallelism
  degree ``N``.  This makes the rust collectives trivial (ring all-gather /
  reduce-scatter over one contiguous buffer, exactly the paper's Figure 1)
  and makes ZDP sharding a plain ``P/N`` slice.  ``pack``/``unpack`` and the
  layout table in the manifest define the mapping.

* **Three artifacts per model config** (see aot.py):
    - ``fwd_loss``:    (params, tokens)           -> loss
    - ``grad_step``:   (params, tokens)           -> (loss, grads)
    - ``adam_full`` / ``adam_shard``: elementwise Adam on the full vector or
      on one ``P/N`` shard (ZDP workers update only their shard after the
      reduce-scatter, exactly as in FSDP).

* **Kernels in the hot path.**  QKV/proj/MLP matmuls go through the Pallas
  ``split_matmul`` kernel (operator splitting, Figure 4); attention through
  the tiled Pallas SDPA; layernorm through the row-blocked Pallas LN.  Each
  gets a ``custom_vjp`` whose backward also runs Pallas matmuls, so the
  lowered HLO keeps the kernel schedules in fwd *and* bwd.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import split_matmul
from .kernels.attention import attention_mha
from .kernels.layernorm import layernorm as pallas_layernorm


# --------------------------------------------------------------------------
# Model configuration
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GPTConfig:
    """Static GPT shape description (mirrors rust/src/model/)."""

    name: str = "tiny"
    vocab: int = 512
    seq: int = 64
    layers: int = 2
    hidden: int = 64
    heads: int = 2
    # Paper §4.1: default slice granularity for operator splitting.
    slice_granularity: int = 4

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads

    def param_count(self) -> int:
        h, v, l, s = self.hidden, self.vocab, self.layers, self.seq
        per_layer = (
            2 * h + 2 * h          # ln1, ln2 (gamma+beta)
            + h * 3 * h + 3 * h    # qkv
            + h * h + h            # proj
            + h * 4 * h + 4 * h    # mlp up
            + 4 * h * h + h        # mlp down
        )
        return v * h + s * h + l * per_layer + 2 * h  # + final LN (head tied)


# Standard configs exposed to the rust side through the manifest.
CONFIGS: Dict[str, GPTConfig] = {
    "tiny": GPTConfig(name="tiny", vocab=512, seq=64, layers=2, hidden=64,
                      heads=2),
    "e2e": GPTConfig(name="e2e", vocab=8192, seq=128, layers=6, hidden=384,
                     heads=6),
    "gpt100m": GPTConfig(name="gpt100m", vocab=32768, seq=256, layers=12,
                         hidden=768, heads=12),
}


# --------------------------------------------------------------------------
# Pallas ops with custom VJPs (kernel fwd + kernel bwd)
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def kmatmul(x: jax.Array, w: jax.Array, granularity: int) -> jax.Array:
    """``x @ w`` through the Pallas split-matmul kernel."""
    return split_matmul(x, w, granularity=granularity)


def _kmatmul_fwd(x, w, granularity):
    return split_matmul(x, w, granularity=granularity), (x, w)


def _kmatmul_bwd(granularity, res, g):
    x, w = res
    # dx = g @ w.T : contraction over the output dim; dw = x.T @ g.
    # granularity=1 keeps the Pallas schedule while staying divisibility-safe
    # for the transposed shapes.
    dx = split_matmul(g, w.T, granularity=1)
    dw = split_matmul(x.T, g, granularity=1)
    return dx, dw


kmatmul.defvjp(_kmatmul_fwd, _kmatmul_bwd)


@jax.custom_vjp
def kattention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal MHA ``(H,S,d)`` through the tiled Pallas kernel."""
    return attention_mha(q, k, v, causal=True)


def _kattention_fwd(q, k, v):
    return attention_mha(q, k, v, causal=True), (q, k, v)


def _kattention_bwd(res, do):
    q, k, v = res
    h, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("hqd,hkd->hqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    dv = jnp.einsum("hqk,hqd->hkd", p, do)
    dp = jnp.einsum("hqd,hkd->hqk", do, v)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("hqk,hkd->hqd", ds, k) * scale
    dk = jnp.einsum("hqk,hqd->hkd", ds, q) * scale
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


kattention.defvjp(_kattention_fwd, _kattention_bwd)


@jax.custom_vjp
def klayernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array) -> jax.Array:
    """LayerNorm ``(R,H)`` through the row-blocked Pallas kernel."""
    return pallas_layernorm(x, gamma, beta)


def _kln_fwd(x, gamma, beta):
    return pallas_layernorm(x, gamma, beta), (x, gamma)


def _kln_bwd(res, dy):
    x, gamma = res
    eps = 1e-5
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = (xf - mu) * rstd
    dyf = dy.astype(jnp.float32)
    dgamma = jnp.sum(dyf * xhat, axis=0)
    dbeta = jnp.sum(dyf, axis=0)
    dg = dyf * gamma
    dx = rstd * (dg - jnp.mean(dg, axis=-1, keepdims=True)
                 - xhat * jnp.mean(dg * xhat, axis=-1, keepdims=True))
    return dx.astype(x.dtype), dgamma.astype(x.dtype), dbeta.astype(x.dtype)


klayernorm.defvjp(_kln_fwd, _kln_bwd)


# --------------------------------------------------------------------------
# Parameter pytree, packing, layout
# --------------------------------------------------------------------------

def init_params(rng: jax.Array, cfg: GPTConfig) -> Dict[str, Any]:
    """GPT-2-style init.  Per-layer tensors are stacked on a leading L axis
    so the forward can ``lax.scan`` over layers (keeps the HLO compact)."""
    h, v, l, s = cfg.hidden, cfg.vocab, cfg.layers, cfg.seq
    ks = jax.random.split(rng, 8)
    std = 0.02
    proj_std = std / (2 * l) ** 0.5  # GPT-2 residual-scaled init

    def nrm(key, shape, sd=std):
        return (jax.random.normal(key, shape, dtype=jnp.float32) * sd)

    return {
        "wte": nrm(ks[0], (v, h)),
        "wpe": nrm(ks[1], (s, h)),
        "ln1_g": jnp.ones((l, h)), "ln1_b": jnp.zeros((l, h)),
        "qkv_w": nrm(ks[2], (l, h, 3 * h)), "qkv_b": jnp.zeros((l, 3 * h)),
        "proj_w": nrm(ks[3], (l, h, h), proj_std), "proj_b": jnp.zeros((l, h)),
        "ln2_g": jnp.ones((l, h)), "ln2_b": jnp.zeros((l, h)),
        "up_w": nrm(ks[4], (l, h, 4 * h)), "up_b": jnp.zeros((l, 4 * h)),
        "down_w": nrm(ks[5], (l, 4 * h, h), proj_std),
        "down_b": jnp.zeros((l, h)),
        "lnf_g": jnp.ones((h,)), "lnf_b": jnp.zeros((h,)),
    }


# Deterministic leaf order shared with the rust side via the manifest.
LEAF_ORDER: List[str] = [
    "wte", "wpe", "ln1_g", "ln1_b", "qkv_w", "qkv_b", "proj_w", "proj_b",
    "ln2_g", "ln2_b", "up_w", "up_b", "down_w", "down_b", "lnf_g", "lnf_b",
]


def layout(cfg: GPTConfig) -> List[Dict[str, Any]]:
    """(name, offset, shape) table for the packed vector — goes in the
    manifest so rust (and humans) can index into the packed buffer."""
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    table, off = [], 0
    for name in LEAF_ORDER:
        shape = tuple(int(d) for d in params[name].shape)
        size = 1
        for d in shape:
            size *= d
        table.append({"name": name, "offset": off, "shape": list(shape),
                      "size": size})
        off += size
    return table


def packed_len(cfg: GPTConfig, pad_to: int = 1) -> int:
    raw = sum(e["size"] for e in layout(cfg))
    return ((raw + pad_to - 1) // pad_to) * pad_to


def pack(params: Dict[str, Any], cfg: GPTConfig, pad_to: int = 1) -> jax.Array:
    flat = jnp.concatenate([params[n].reshape(-1) for n in LEAF_ORDER])
    total = packed_len(cfg, pad_to)
    return jnp.pad(flat, (0, total - flat.shape[0]))


def unpack(packed: jax.Array, cfg: GPTConfig) -> Dict[str, Any]:
    out = {}
    for e in layout(cfg):
        out[e["name"]] = jax.lax.dynamic_slice(
            packed, (e["offset"],), (e["size"],)
        ).reshape(e["shape"])
    return out


# --------------------------------------------------------------------------
# Forward + loss
# --------------------------------------------------------------------------

def _block(cfg: GPTConfig, x: jax.Array, lp: Dict[str, jax.Array]) -> jax.Array:
    """One transformer block over ``(B*S, H)`` rows (layer params ``lp``)."""
    g = cfg.slice_granularity if cfg.hidden % cfg.slice_granularity == 0 else 1
    bs_rows, h = x.shape
    hd, nh = cfg.head_dim, cfg.heads
    b = bs_rows // cfg.seq

    a = klayernorm(x, lp["ln1_g"], lp["ln1_b"])
    qkv = kmatmul(a, lp["qkv_w"], g) + lp["qkv_b"]
    qkv = qkv.reshape(b, cfg.seq, 3, nh, hd)
    q = qkv[:, :, 0].transpose(0, 2, 1, 3).reshape(b * nh, cfg.seq, hd)
    k = qkv[:, :, 1].transpose(0, 2, 1, 3).reshape(b * nh, cfg.seq, hd)
    v = qkv[:, :, 2].transpose(0, 2, 1, 3).reshape(b * nh, cfg.seq, hd)
    o = kattention(q, k, v)
    o = (o.reshape(b, nh, cfg.seq, hd).transpose(0, 2, 1, 3)
          .reshape(bs_rows, h))
    x = x + kmatmul(o, lp["proj_w"], g) + lp["proj_b"]

    m = klayernorm(x, lp["ln2_g"], lp["ln2_b"])
    u = jax.nn.gelu(kmatmul(m, lp["up_w"], g) + lp["up_b"])
    x = x + kmatmul(u, lp["down_w"], g) + lp["down_b"]
    return x


def forward(params: Dict[str, Any], tokens: jax.Array,
            cfg: GPTConfig) -> jax.Array:
    """Logits ``(B, S, V)`` for input tokens ``(B, S)``."""
    b, s = tokens.shape
    x = params["wte"][tokens] + params["wpe"][None, :s, :]
    x = x.reshape(b * s, cfg.hidden)

    def body(x, lp):
        return _block(cfg, x, lp), None

    layer_params = {k: params[k] for k in (
        "ln1_g", "ln1_b", "qkv_w", "qkv_b", "proj_w", "proj_b",
        "ln2_g", "ln2_b", "up_w", "up_b", "down_w", "down_b")}
    x, _ = jax.lax.scan(body, x, layer_params)
    x = klayernorm(x, params["lnf_g"], params["lnf_b"])
    logits = jnp.dot(x, params["wte"].T,
                     preferred_element_type=jnp.float32)  # tied head
    return logits.reshape(b, s, cfg.vocab)


def loss_fn(packed: jax.Array, tokens: jax.Array, cfg: GPTConfig) -> jax.Array:
    """Mean next-token cross-entropy.  ``tokens`` is ``(B, S+1)``."""
    params = unpack(packed, cfg)
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(params, inp, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)
    return jnp.mean(nll)


def grad_step(packed: jax.Array, tokens: jax.Array,
              cfg: GPTConfig) -> Tuple[jax.Array, jax.Array]:
    """(loss, packed grads) — the per-worker compute of one iteration."""
    loss, grads = jax.value_and_grad(loss_fn)(packed, tokens, cfg)
    return loss, grads


# --------------------------------------------------------------------------
# Adam (elementwise over the packed vector or any shard of it)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8


def adam_update(p: jax.Array, g: jax.Array, m: jax.Array, v: jax.Array,
                step: jax.Array, opt: AdamConfig = AdamConfig()
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One Adam step on a 1-D slice.  ``step`` is 1-based (int32 scalar).

    Elementwise, so ZDP workers apply it to their ``P/N`` shard only —
    this is exactly ZeRO's partitioned optimizer update.
    """
    t = step.astype(jnp.float32)
    m2 = opt.b1 * m + (1 - opt.b1) * g
    v2 = opt.b2 * v + (1 - opt.b2) * jnp.square(g)
    mhat = m2 / (1 - opt.b1 ** t)
    vhat = v2 / (1 - opt.b2 ** t)
    p2 = p - opt.lr * mhat / (jnp.sqrt(vhat) + opt.eps)
    return p2, m2, v2
