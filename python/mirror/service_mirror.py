"""Python mirror of the plan service's search-facing math (PR 5
validation, in the tradition of frontier_mirror.py / scope_mirror.py —
this container has no Rust toolchain, so the load-bearing arithmetic is
re-validated op-for-op in IEEE-754 doubles here).

Mirrors:

* ``planner/greedy.rs::search_from`` — the warm-seed **repair** stage:
  greedy downgrades from the neighbor plan until it fits the queried
  (limit, batch);
* ``planner/bound.rs::SearchSpace::offer_warm`` — warm-seed pricing in
  search arithmetic (base_time + grid time_fixed sum in visit order),
  feasibility gating, and the (time, lex) install rule against the
  greedy seed;
* ``service/key.rs`` — the two-lane FNV-1a/64 fingerprint, including the
  cross-language reference vectors baked into the Rust unit test.

Checks:

1. **Warm-start bit-identity** (the ISSUE-5 acceptance): on hundreds of
   random instances, for the folded and frontier engines, a search
   seeded with ANY warm vector — neighboring-batch optima, other-limit
   optima, random feasible plans, random infeasible plans, malformed
   junk — returns the bit-identical (time, full choice vector) result of
   the cold search, never exploring more nodes.
2. **Strict pruning exists**: across the instances, warm seeds strictly
   reduce node counts somewhere (else the warm path would be dead
   weight).
3. **24L-style sweep**: the neighboring-batch warm-start procedure of
   rust/tests/plan_service.rs::warm_start_reduces_nodes_on_the_24l_sweep
   — per-batch warm from the adjacent batch's winner — is bit-identical
   everywhere and strictly reduces nodes for at least one (limit, batch,
   neighbor) combination.
4. **FNV lanes**: the mirror implementation reproduces the reference
   vectors asserted in rust/src/service/key.rs, and fingerprints
   separate search-relevant table changes while ignoring irrelevant
   ones.

Run: ``python3 python/mirror/service_mirror.py`` (exits non-zero on any
mismatch).
"""

import random
import sys

import frontier_mirror as fm


# ----------------------------------------------------- offer_warm mirror


def repair(tables, start, limit, b):
    """greedy.rs::search_from, op for op: downgrade `start` along the
    best dmem/dtime moves until it fits; None when malformed or
    unrepairable."""
    n = len(tables)
    if len(start) != n or any(
            not (0 <= c < len(t.tf)) for c, t in zip(start, tables)):
        return None
    choice = list(start)
    _, peak = fm.evaluate(tables, choice, b)
    while peak > limit:
        best = None
        for i in range(n):
            t = tables[i]
            cur = choice[i]
            for c in range(cur + 1, len(t.tf)):
                dmem = (t.st[cur] - t.st[c]) + max(t.g[cur] - t.g[c], 0.0)
                dtime = t.tf[c] - t.tf[cur]
                if dmem <= 0.0:
                    continue
                ratio = dmem / max(dtime, 1e-15)
                if best is None or ratio > best[2]:
                    best = (i, c, ratio)
        if best is None:
            return None
        choice[best[0]] = best[1]
        _, peak = fm.evaluate(tables, choice, b)
    return choice


def offer_warm(space, choice):
    """bound.rs::SearchSpace::offer_warm, op for op."""
    if len(choice) != space.n():
        return False
    tf = 0.0
    st = 0.0
    tm = 0.0
    ordered = []
    for i, op in enumerate(space.pre.order):
        c = choice[op]
        if not (0 <= c < len(space.flat[i])):
            return False
        opt = space.flat[i][c]
        tf += opt[0]
        st += opt[1]
        tm = max(tm, opt[2])
        ordered.append(c)
    if st + space.base_act + tm > space.limit:
        return False
    total = space.base_time + tf
    better = (space.seed is None or total < space.seed[0]
              or (total == space.seed[0]
                  and fm.lex_less(ordered, space.seed[1])))
    if better:
        space.seed = (total, ordered)
    return True


def run_engine_warm(tables, limit, b, engine, warm=None, frontiers=None,
                    pre=None):
    """fm.run_engine with an optional warm seed repaired + installed
    first (dfs.rs::search_prefolded's seeding path)."""
    pre = pre or fm.Prefold(tables)
    space = fm.Space(pre, tables, limit, b)
    if warm is not None:
        repaired = repair(tables, warm, limit, b)
        if repaired is not None:
            offer_warm(space, repaired)
    if engine == "frontier" and frontiers is None:
        frontiers = fm.build_frontiers(pre, tables)
    w = fm.Walker(space, frontiers)
    if engine == "folded":
        w.descend_folded(0, 0.0, 0.0, 0.0)
    else:
        w.descend_frontier(0, 0.0, 0.0, 0.0)
    if w.best is None:
        return None
    return w.best_time, space.unpermute(w.best), w.nodes


# ------------------------------------------------------------ fnv mirror

FNV_OFFSET = 0xCBF29CE484222325
FNV_OFFSET_ALT = 0x9E3779B97F4A7C15
FNV_PRIME = 0x100000001B3
MASK = (1 << 64) - 1


def fnv_words(words, offset):
    h = offset
    for w in words:
        for byte in int(w).to_bytes(8, "little"):
            h ^= byte
            h = (h * FNV_PRIME) & MASK
    return h


def f64_bits(x):
    import struct

    return struct.unpack("<Q", struct.pack("<d", float(x)))[0]


def fingerprint(tables, epoch=5, n_devices=8, dpn=8):
    """service/key.rs::fingerprint over the mirror's Table.key() fields
    (act, ws, gamma, then per-option tf/st/g — the same order
    cost/menu.rs::table_key emits)."""
    words = [epoch, n_devices, dpn, len(tables)]
    for t in tables:
        bits = [f64_bits(t.act), f64_bits(t.ws), f64_bits(t.gamma)]
        for c in range(len(t.tf)):
            bits.extend(
                [f64_bits(t.tf[c]), f64_bits(t.st[c]), f64_bits(t.g[c])])
        words.append(len(bits))
        words.extend(bits)
    return (fnv_words(words, FNV_OFFSET), fnv_words(words, FNV_OFFSET_ALT))


def check(cond, msg, ctx):
    if not cond:
        print("FAIL:", msg)
        print("  ctx:", ctx)
        sys.exit(1)


# ---------------------------------------------------------------- checks


def random_feasible(rng, tables, limit, b, tries=60):
    for _ in range(tries):
        cand = [rng.randrange(len(t.tf)) for t in tables]
        if fm.evaluate(tables, cand, b)[1] <= limit:
            return cand
    return None


def warm_seeds_for(rng, tables, limit, b):
    """The seed menagerie the Rust property test uses."""
    seeds = []
    for nb, nlimit in [(max(1, b - 1), limit), (b + 1, limit),
                       (b, limit * 0.8), (b, limit * 1.3)]:
        r = fm.run_engine(tables, nlimit, nb, "folded")
        if r is not None:
            seeds.append(r[1])
    feas = random_feasible(rng, tables, limit, b)
    if feas:
        seeds.append(feas)
    # junk: wrong length, wild indices, random (possibly infeasible)
    seeds.append([0] * (len(tables) + 3))
    seeds.append([10 ** 9] * len(tables))
    seeds.append([rng.randrange(len(t.tf)) for t in tables])
    return seeds


def main():
    # ---- fnv reference vectors (shared with rust/src/service/key.rs)
    check(fnv_words([0x6F736470], FNV_OFFSET) == 0xC57ABE0D2D2377BB,
          "fnv lane 0 reference vector", hex(fnv_words([0x6F736470],
                                                       FNV_OFFSET)))
    check(fnv_words([0x6F736470], FNV_OFFSET_ALT) == 0x065FA0A7968E0C6B,
          "fnv lane 1 reference vector", hex(fnv_words([0x6F736470],
                                                       FNV_OFFSET_ALT)))

    # ---- fingerprints separate search-relevant changes only
    rng = random.Random(0x5E41)
    tables = fm.rand_instance(rng)
    base = fingerprint(tables)
    check(fingerprint(tables) == base, "fingerprint not deterministic", "")
    bumped = fingerprint(tables, epoch=6)
    check(bumped != base, "epoch must change the fingerprint", "")
    check(fingerprint(tables, n_devices=4) != base,
          "cluster shape must change the fingerprint", "")
    # a one-ulp cost change splits the key
    import copy

    t2 = copy.deepcopy(tables)
    t2[0].st[0] += 1.0
    check(fingerprint(t2) != base, "cost change must change the key", "")
    print("fnv + fingerprint mirrors OK")

    # ---- warm-start bit-identity on random instances
    full = 0
    strict_prunes = 0
    warm_checked = 0
    for trial in range(500):
        tables = fm.rand_instance(rng)
        b = rng.randint(1, 6)
        dp_peak = fm.evaluate(tables, [0] * len(tables), b)[1]
        limit = dp_peak * (0.2 + rng.random() * 1.2)
        ctx = f"trial {trial} b={b} limit={limit}"

        for engine in ("folded", "frontier"):
            cold = run_engine_warm(tables, limit, b, engine)
            for seed in warm_seeds_for(rng, tables, limit, b):
                warm = run_engine_warm(tables, limit, b, engine, warm=seed)
                warm_checked += 1
                if cold is None:
                    check(warm is None,
                          f"warm seed changed feasibility ({engine})", ctx)
                    continue
                check(warm is not None,
                      f"warm seed lost feasibility ({engine})", ctx)
                check(warm[0] == cold[0] and warm[1] == cold[1],
                      f"warm result differs ({engine}): "
                      f"{warm[:2]} vs {cold[:2]}", ctx)
                check(warm[2] <= cold[2],
                      f"warm explored more nodes ({engine}): "
                      f"{warm[2]} > {cold[2]}", ctx)
                if warm[2] < cold[2]:
                    strict_prunes += 1
            if cold is not None:
                full += 1
    # strictness is asserted on the 24L-style sweep below (random tiny
    # trees usually find the optimum at their first leaves, leaving an
    # incumbent nothing to prune) — here the property is bit-identity
    print(f"warm bit-identity: {full} engine-runs, {warm_checked} warm "
          f"searches, all bit-exact; {strict_prunes} strictly pruned")

    # ---- the 24L-style neighboring-batch procedure (mirrors the Rust
    # acceptance test warm_start_reduces_nodes_on_the_24l_sweep)
    grid = lambda v: v * fm.TIME_GRID * 1000
    big_a = ([grid(10), grid(35)], [4000.0, 500.0], [0.0, 3500.0], 64, 16,
             2e-5)
    big_b = ([grid(8), grid(30)], [3000.0, 380.0], [0.0, 2600.0], 48, 12,
             1.5e-5)
    emb = ([grid(4), grid(18)], [9000.0, 1200.0], [0.0, 7800.0], 8, 4, 1e-5)
    head = ([grid(5), grid(20)], [9000.0, 1150.0], [0.0, 7900.0], 8, 4,
            1e-5)
    tables = ([fm.Table(*big_a) for _ in range(24)]
              + [fm.Table(*big_b) for _ in range(24)]
              + [fm.Table(*emb), fm.Table(*head)])
    pre = fm.Prefold(tables)
    fr = fm.build_frontiers(pre, tables)
    dp_peak = fm.evaluate(tables, [0] * len(tables), 1)[1]
    strict_seen = False
    rows = []
    for frac in (0.35, 0.5, 0.65, 0.8):
        limit = dp_peak * frac
        sweep = []
        for b in range(1, 9):
            r = run_engine_warm(tables, limit, b, "frontier",
                                frontiers=fr, pre=pre)
            if r is None:
                break
            sweep.append(r)
        for b in range(1, len(sweep) + 1):
            for nb in (b - 1, b + 1):
                if nb < 1 or nb > len(sweep) or nb == b:
                    continue
                seed = sweep[nb - 1][1]
                cold = sweep[b - 1]
                warm = run_engine_warm(tables, limit, b, "frontier",
                                       warm=seed, frontiers=fr, pre=pre)
                ctx = f"24L frac={frac} b={b} nb={nb}"
                check(warm is not None and warm[0] == cold[0]
                      and warm[1] == cold[1], "24L warm differs", ctx)
                check(warm[2] <= cold[2], "24L warm explored more", ctx)
                if warm[2] < cold[2]:
                    strict_seen = True
                    rows.append((frac, b, nb, cold[2], warm[2]))
    check(strict_seen,
          "no neighboring-batch warm start strictly pruned on the "
          "24L-style sweep", "")
    print("24L-style neighboring-batch warm starts bit-exact; strict "
          f"node reductions at {len(rows)} (frac, b, nb) points, e.g. "
          f"{rows[:4]}")
    print("OK: all service-mirror checks passed")


if __name__ == "__main__":
    main()
