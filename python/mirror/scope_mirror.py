"""Python mirror of the hybrid sharding-scope cost model (ISSUE 4
validation).

Mirrors, operation-for-operation in IEEE-754 doubles, the Rust added in
this PR:

* ``cost/time.rs``   — ``scope_ring``, ``inter_node_grad_time``, the
  scope-aware ``op_comm_time`` (DP slices on the flat N-ring, ZDP slices
  on the scope ring + hierarchical cross-node shard reduce);
* ``cost/memory.rs`` — states divided by the scope's group size;
* ``cost/menu.rs``   — the dominance filter over
  (time_fixed, states, gather);
* ``sim/mod.rs``     — the per-phase decomposition (fwd gather, bwd
  gather, scoped grad RS, cross-node shard reduce) whose serial sum must
  equal ``op_comm_time``;
* ``collectives/mod.rs`` — ``hier_gather_model_seconds`` vs the flat ring.

Checks:

1. scope identities — global scope reproduces the pre-scope formula
   bit-for-bit; node scope on a single node equals global bit-for-bit;
2. sim decomposition — per-phase sums equal ``op_comm_time`` (tolerance
   1e-12 relative) for random ops x decisions x scopes x clusters;
3. menu shape — on a two-node cluster node-ZDP survives the dominance
   filter as a distinct Pareto point (faster than global ZDP, more
   states), and menus grow <= 2x;
4. the acceptance inequality — on the two_server_a100 cluster with a
   memory limit that forces sharding, the brute-force optimum over the
   scoped space uses node scope on >= 1 op and its throughput strictly
   beats (a) the best all-global-ZDP operating point and (b) the
   brute-force optimum of the scope-free space;
5. hierarchical gather — the two-phase analytic model beats the flat
   bottleneck ring for every tested (n, dpn) with a slow inter link.

Run: ``python3 python/mirror/scope_mirror.py`` (exits non-zero on any
violation).
"""

import itertools
import random
import sys

GIB = 1024.0**3


# --- cluster -------------------------------------------------------------

class Cluster:
    def __init__(self, n, dpn, mem, ai, bi, ax, bx, flops):
        self.n = n
        self.dpn = dpn
        self.mem = mem
        self.ai, self.bi, self.ax, self.bx = ai, bi, ax, bx
        self.flops = flops

    def n_nodes(self):
        return -(-self.n // self.dpn)

    def crosses(self):
        return self.n > self.dpn

    def ring_link(self):
        return (self.ax, self.bx) if self.crosses() else (self.ai, self.bi)


def two_server_a100(mem_gib):
    return Cluster(16, 8, mem_gib * GIB, 5e-6, 1 / 200e9, 30e-6, 1 / 12.5e9,
                   19.5e12)


def rtx_titan(n, mem_gib):
    return Cluster(n, n, mem_gib * GIB, 10e-6, 1 / 12e9, 10e-6, 1 / 12e9,
                   14e12)


# --- decisions -----------------------------------------------------------

GLOBAL, NODE = "global", "node"


class D:
    def __init__(self, g, z, scope=GLOBAL):
        self.g, self.z, self.scope = g, z, scope

    def slices(self):
        return max(self.g, 1)

    def frac(self):
        return self.z / self.slices()

    def node_scoped(self):
        return self.scope == NODE and self.z > 0


def group_size(c, scope):
    return c.n if scope == GLOBAL else min(c.dpn, c.n)


def scope_ring(c, scope):
    if scope == GLOBAL:
        a, b = c.ring_link()
        return a, b, c.n
    return c.ai, c.bi, min(c.dpn, c.n)


def comm_rounds(zdp, ck):
    return (4.0 if ck else 3.0) if zdp else 2.0


def inter_node_grad_time(slice_bytes, c):
    nodes = c.n_nodes()
    if nodes <= 1:
        return 0.0
    group = float(min(c.dpn, c.n))
    shard = slice_bytes / group
    return 2.0 * (nodes - 1.0) * (c.ax + shard * c.bx / nodes)


def op_comm_time(pb, d, c, ck):
    """Mirror of cost/time.rs::op_comm_time for a shardable op of
    param_bytes pb."""
    if c.n == 1:
        return 0.0
    g = float(d.slices())
    slice_bytes = pb / g
    zdp, dp = float(d.z), g - d.z
    alpha, beta = c.ring_link()
    per_dp = (c.n - 1.0) * comm_rounds(False, ck) * (
        alpha + slice_bytes * beta / c.n)
    sa, sb, ring = scope_ring(c, d.scope)
    rf = float(ring)
    per_zdp = (rf - 1.0) * comm_rounds(True, ck) * (
        sa + slice_bytes * sb / rf)
    if d.scope == NODE:
        per_zdp += inter_node_grad_time(slice_bytes, c)
    return dp * per_dp + zdp * per_zdp


def op_states(sb, d, c):
    """Mirror of cost/memory.rs states term (state_bytes sb)."""
    zf = d.frac()
    return sb * ((1.0 - zf) + zf / group_size(c, d.scope))


def op_gather(pb, d):
    return 2.0 * pb / d.slices() if d.z > 0 else 0.0


# --- sim decomposition (mirror of sim/mod.rs) ----------------------------

def flat_comm_seconds(pb, d, c, rounds):
    if c.n == 1:
        return 0.0
    a, b = c.ring_link()
    return rounds * (c.n - 1.0) * (d.slices() * a + pb * b / c.n)


def scoped_comm_seconds(pb, d, c, rounds):
    if c.n == 1:
        return 0.0
    a, b, ring = scope_ring(c, d.scope)
    if ring <= 1:
        return 0.0
    return rounds * (ring - 1.0) * (d.slices() * a + pb * b / ring)


def inter_sync_seconds(pb, d, c):
    if d.scope != NODE:
        return 0.0
    nodes = c.n_nodes()
    if nodes <= 1 or c.n == 1:
        return 0.0
    group = float(min(c.dpn, c.n))
    return 2.0 * (nodes - 1.0) * (
        d.slices() * c.ax + (pb / group) * c.bx / nodes)


def sim_comm_sum(pb, d, c, ck):
    f = d.frac()
    fwd = scoped_comm_seconds(pb, d, c, 1.0) * f
    bwd = scoped_comm_seconds(pb, d, c, 2.0 if ck else 1.0) * f
    sync = (flat_comm_seconds(pb, d, c, 2.0) * (1.0 - f)
            + scoped_comm_seconds(pb, d, c, 1.0) * f)
    inter = inter_sync_seconds(pb, d, c) * f
    return fwd + bwd + sync + inter


# --- menu ----------------------------------------------------------------

def menu(pb, sb, c, grans, hybrid):
    scopes = [GLOBAL, NODE] if (c.crosses() and hybrid) else [GLOBAL]
    cands = []
    for g in grans:
        for z in range(0, max(g, 1) + 1):
            for sc in scopes:
                if z == 0 and sc != GLOBAL:
                    continue
                cands.append(D(g, z, sc))
    pts = [(op_comm_time(pb, d, c, False), op_states(sb, d, c),
            op_gather(pb, d), d) for d in cands]
    keep = []
    for p in pts:
        dominated = any(
            q is not p
            and q[0] <= p[0] and q[1] <= p[1] and q[2] <= p[2]
            and (q[0] < p[0] or q[1] < p[1] or q[2] < p[2])
            for q in pts)
        if dominated:
            continue
        if any(k[0] == p[0] and k[1] == p[1] and k[2] == p[2]
               for k in keep):
            continue
        keep.append(p)
    keep.sort(key=lambda p: p[0])
    return keep


def hier_gather_model(bytes_, n, dpn, ai, bi, ax, bx):
    if n <= 1:
        return 0.0
    if dpn == 0 or n == dpn or n % dpn:
        a, b = (ax, bx) if n > dpn else (ai, bi)
        return (n - 1.0) * (a + bytes_ * b / n)
    nodes = n / dpn
    return ((dpn - 1.0) * (ai + bytes_ / n * bi)
            + (nodes - 1.0) * (ax + bytes_ / nodes * bx))


fails = 0


def check(ok, msg):
    global fails
    if not ok:
        fails += 1
        print(f"FAIL: {msg}")


def main():
    rng = random.Random(0xC0DE5)

    # 1. scope identities ------------------------------------------------
    for _ in range(300):
        n = rng.choice([2, 4, 8, 16])
        c = rtx_titan(n, 8.0)  # single node
        pb = rng.uniform(1e4, 1e9)
        g = rng.choice([0, 2, 4])
        z = rng.randint(0, max(g, 1))
        ck = rng.random() < 0.5
        a = op_comm_time(pb, D(g, z, GLOBAL), c, ck)
        b = op_comm_time(pb, D(g, z, NODE), c, ck)
        check(a == b, f"single-node scope identity: {a} != {b}")
        # pre-scope formula (the seed's op_comm_time), global scope: the
        # seed computed `dp * per_slice(k)` with
        # per_slice(k) = (n-1) * k * (alpha + slice_bytes*beta/n) — keep
        # the exact association so the bit-identity claim is meaningful
        alpha, beta = c.ring_link()
        gg = max(g, 1)
        slice_bytes = pb / gg

        def per_slice(k):
            return (n - 1.0) * k * (alpha + slice_bytes * beta / n)

        legacy = ((gg - z) * per_slice(comm_rounds(False, ck))
                  + z * per_slice(comm_rounds(True, ck)))
        check(a == legacy, f"global scope != legacy formula: {a} {legacy}")

    # 2. sim decomposition sums ------------------------------------------
    for _ in range(500):
        c = rng.choice([two_server_a100(16.0), rtx_titan(8, 8.0),
                        Cluster(8, 2, 8 * GIB, 1e-6, 1e-11, 2e-5, 8e-10,
                                1e13),
                        Cluster(8, 4, 8 * GIB, 1e-6, 1e-11, 2e-5, 8e-10,
                                1e13)])
        pb = rng.uniform(1e4, 1e9)
        g = rng.choice([0, 2, 8])
        z = rng.randint(0, max(g, 1))
        sc = rng.choice([GLOBAL, NODE])
        ck = rng.random() < 0.5
        d = D(g, z, sc)
        t_model = op_comm_time(pb, d, c, ck)
        t_sim = sim_comm_sum(pb, d, c, ck)
        rel = abs(t_sim - t_model) / max(t_model, 1e-30)
        check(rel < 1e-12,
              f"sim decomposition != op_comm_time: {t_sim} {t_model}")

    # 3. menu shape on the two-server cluster ----------------------------
    c = two_server_a100(16.0)
    pb = 4 * 512 * 2048.0  # the mlp_up of the acceptance model
    sb = 16.0 * pb / 4.0
    scoped = menu(pb, sb, c, [0], True)
    flat = menu(pb, sb, c, [0], False)
    check(len(scoped) <= 2 * len(flat), "menu grew more than 2x")
    gzdp = [p for p in scoped if p[3].z > 0 and p[3].scope == GLOBAL]
    nzdp = [p for p in scoped if p[3].node_scoped()]
    check(gzdp and nzdp, "both ZDP scopes must survive the filter")
    check(nzdp[0][0] < gzdp[0][0], "node ZDP must be faster")
    check(nzdp[0][1] > gzdp[0][1], "node ZDP must keep more states")
    check(all(not p[3].node_scoped() for p in flat),
          "scope-free menu contains node entries")
    single = menu(pb, sb, rtx_titan(8, 8.0), [0], True)
    check(all(not p[3].node_scoped() for p in single),
          "single-node menu contains node entries")

    # 4. acceptance inequality (brute force over a paper-granularity GPT)
    # 4 layers x (attn-block, mlp-block) + embed + head, hidden 512 — the
    # same shape rust/tests/hybrid_scopes.rs plans over, coarsely.
    h, seq, vocab, layers = 512, 128, 4000, 4
    ops = []
    emb_pb = 4.0 * vocab * h
    ops.append(dict(pb=emb_pb, sb=16 * vocab * h, act=4.0 * seq * h))
    for _ in range(layers):
        attn_pb = 4.0 * 4 * h * h
        mlp_pb = 4.0 * 8 * h * h
        ops.append(dict(pb=attn_pb, sb=4 * attn_pb,
                        act=4.0 * seq * h * 4))
        ops.append(dict(pb=mlp_pb, sb=4 * mlp_pb,
                        act=4.0 * seq * h * 6))
    ops.append(dict(pb=emb_pb, sb=16 * vocab * h, act=4.0 * seq * vocab))
    state_total = sum(o["sb"] for o in ops)
    c = two_server_a100(16.0)
    c.mem = state_total * 0.6  # forces sharding (all-DP cannot fit)
    flops_ps = [6.0 * o["pb"] / 4.0 * seq for o in ops]

    def eff(b):
        return b / (b + 2.0)

    def plan_cost(choice, menus, b):
        tf = sum(menus[i][ci][0] for i, ci in enumerate(choice))
        comp = sum(b * f / c.flops for f in flops_ps) / eff(b)
        states = sum(menus[i][ci][1] for i, ci in enumerate(choice))
        act = sum(b * o["act"] for o in ops)
        trans = max(menus[i][ci][2] for i, ci in enumerate(choice))
        return tf + comp, states + act + trans

    def best_plan(menus, b):
        best = None
        for choice in itertools.product(
                *[range(len(m)) for m in menus]):
            t, mem = plan_cost(choice, menus, b)
            if mem <= c.mem and (best is None or t < best[0]):
                best = (t, choice)
        return best

    menus_s = [menu(o["pb"], o["sb"], c, [0], True) for o in ops]
    menus_f = [menu(o["pb"], o["sb"], c, [0], False) for o in ops]
    tp_s = tp_f = tp_z = 0.0
    plan_s = None
    for b in range(1, 9):
        s = best_plan(menus_s, b)
        if s and b * c.n / s[0] > tp_s:
            tp_s, plan_s = b * c.n / s[0], (b, s[1])
        f = best_plan(menus_f, b)
        if f:
            tp_f = max(tp_f, b * c.n / f[0])
        # all-global-ZDP operating point
        zchoice = []
        for m in menus_s:
            idx = [i for i, p in enumerate(m)
                   if p[3].z > 0 and p[3].scope == GLOBAL
                   and p[3].z == p[3].slices()]
            zchoice.append(idx[0])
        t, mem = plan_cost(zchoice, menus_s, b)
        if mem <= c.mem:
            tp_z = max(tp_z, b * c.n / t)
    check(plan_s is not None, "scoped space infeasible?!")
    b, choice = plan_s
    used_node = sum(menus_s[i][ci][3].node_scoped()
                    for i, ci in enumerate(choice))
    check(used_node >= 1, "optimum does not use node scope")
    check(tp_s > tp_z,
          f"scoped optimum {tp_s:.1f} !> all-global-ZDP {tp_z:.1f}")
    check(tp_s > tp_f,
          f"scoped optimum {tp_s:.1f} !> scope-free optimum {tp_f:.1f}")
    print(f"acceptance: b={b}, node-scoped ops {used_node}/{len(ops)}, "
          f"throughput scoped {tp_s:.1f} vs global-ZDP {tp_z:.1f} vs "
          f"scope-free {tp_f:.1f} samples/s")

    # 5. hierarchical gather model ---------------------------------------
    for (n, dpn) in [(4, 2), (8, 4), (8, 2), (16, 8), (6, 3)]:
        for bytes_ in [1e5, 1e7, 1e9]:
            hier = hier_gather_model(bytes_, n, dpn, 1e-6, 1e-11, 2e-5,
                                     8e-10)
            flat = (n - 1.0) * (2e-5 + bytes_ * 8e-10 / n)
            check(hier < flat,
                  f"hier gather not faster: n={n} dpn={dpn} {hier} {flat}")

    if fails:
        print(f"{fails} FAILURES")
        return 1
    print("scope_mirror: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
