"""Python mirror of the Rust planner's frontier engine (PR 3 validation).

Mirrors, operation-for-operation in IEEE-754 doubles:

* ``planner/bound.rs``  — Prefold order, suffix bounds, the folded
  branch-and-bound Walker (greedy seed pricing, strict/tie time pruning,
  memory pruning, fast completion);
* ``planner/frontier.rs`` — the per-class composition-frontier build
  ((time, lex) processing + 2-D staircase prune) and the frontier descent,
  including the too-wide fallback;
* ``planner/exhaustive.rs`` — the folded (time, lex) ground-truth
  enumerator.

Checks, on hundreds of random instances x batch sizes x memory limits:

1. folded B&B  == brute force over the raw product space, bit-for-bit
   (total time bits AND full choice vector — the canonical (total, lex)
   objective);
2. frontier    == folded B&B, bit-for-bit, with node count <= folded's;
3. frontier with a forced too-wide class == folded B&B (fallback path);
4. folded exhaustive == brute force, bit-for-bit;
5. one shared frontier build serves a whole batch sweep (batch
   invariance): per-batch results equal fresh builds at every b;
6. the parallel split over the leading classes' frontier points
   (``enumerate_tasks_frontier`` + the deterministic (time, lex) merge)
   equals the serial frontier engine at every split depth.

Run: ``python3 python/mirror/frontier_mirror.py`` (exits non-zero on any
mismatch; prints node-count evidence for the 24-layer-style instance).
"""

import random
import sys
from itertools import product

TIME_GRID = 1.0 / (1 << 30)


def snap(t):
    # exact for grid multiples; synthetic menus only use grid multiples
    return round(t * (1 << 30)) * TIME_GRID


# ----------------------------------------------------------------- model


class Table:
    def __init__(self, tf, st, g, act, ws, gamma):
        # menus sorted fastest-first, like cost/menu.rs emits
        order = sorted(range(len(tf)), key=lambda i: tf[i])
        self.tf = [tf[i] for i in order]
        self.st = [float(st[i]) for i in order]
        self.g = [float(g[i]) for i in order]
        self.act = float(act)
        self.ws = float(ws)
        self.gamma = gamma

    def key(self):
        return (self.act, self.ws, self.gamma, tuple(self.tf),
                tuple(self.st), tuple(self.g))


def batch_eff(b):
    return b / (b + 2.0)


def base_time(tables, b):
    compute = sum(b * t.gamma for t in tables)
    return snap(compute / batch_eff(b))


def evaluate(tables, choice, b):
    """profiler.evaluate mirror: (time, peak)."""
    tf = 0.0
    compute = 0.0
    persistent = 0.0
    trans = 0.0
    for t, c in zip(tables, choice):
        tf += t.tf[c]
        compute += b * t.gamma
        persistent += t.st[c] + b * t.act
        trans = max(trans, t.g[c] + b * t.ws)
    return tf + compute / batch_eff(b), persistent + trans


def total_of(tables, order, ordered, b):
    """Search-arithmetic total: base + grid tf sum in visit order."""
    tf = 0.0
    for pos, c in enumerate(ordered):
        tf += tables[order[pos]].tf[c]
    return base_time(tables, b) + tf


# --------------------------------------------------------------- prefold


class Prefold:
    def __init__(self, tables):
        n = len(tables)
        base = sorted(range(n), key=lambda i: -tables[i].st[0])
        # stable sort: ties keep profiler order (python sort is stable)
        keys = {}
        cid = []
        for i in range(n):
            k = tables[i].key()
            cid.append(keys.setdefault(k, len(keys)))
        members = [[] for _ in keys]
        for op in base:
            members[cid[op]].append(op)
        self.order = []
        self.class_start = []
        placed = [False] * len(keys)
        for op in base:
            c = cid[op]
            if not placed[c]:
                placed[c] = True
                self.class_start.append(len(self.order))
                self.order.extend(members[c])
        self.class_start.append(n)
        self.suffix_min_time = [0.0] * (n + 1)
        self.suffix_min_states = [0.0] * (n + 1)
        self.suffix_opt0_states = [0.0] * (n + 1)
        for i in reversed(range(n)):
            t = tables[self.order[i]]
            self.suffix_min_time[i] = self.suffix_min_time[i + 1] + t.tf[0]
            self.suffix_min_states[i] = (self.suffix_min_states[i + 1]
                                         + min(t.st))
            self.suffix_opt0_states[i] = (self.suffix_opt0_states[i + 1]
                                          + t.st[0])

    def n(self):
        return len(self.order)

    def n_classes(self):
        return len(self.class_start) - 1

    def mult(self, k):
        return self.class_start[k + 1] - self.class_start[k]


def next_monotone_block(block, o):
    for p in reversed(range(len(block))):
        if block[p] + 1 < o:
            v = block[p] + 1
            for q in range(p, len(block)):
                block[q] = v
            return True
    return False


# ---------------------------------------------------------------- greedy


def greedy(tables, limit, b):
    n = len(tables)
    choice = [0] * n
    _, peak = evaluate(tables, choice, b)
    while peak > limit:
        best = None
        for i in range(n):
            t = tables[i]
            cur = choice[i]
            for c in range(cur + 1, len(t.tf)):
                dmem = (t.st[cur] - t.st[c]) + max(t.g[cur] - t.g[c], 0.0)
                dtime = t.tf[c] - t.tf[cur]
                if dmem <= 0.0:
                    continue
                ratio = dmem / max(dtime, 1e-15)
                if best is None or ratio > best[2]:
                    best = (i, c, ratio)
        if best is None:
            return None
        choice[best[0]] = best[1]
        _, peak = evaluate(tables, choice, b)
    return choice


# ---------------------------------------------------------------- spaces


class Space:
    def __init__(self, pre, tables, limit, b):
        self.pre = pre
        self.tables = tables
        self.limit = limit
        n = pre.n()
        bf = float(b)
        self.flat = []
        for op in pre.order:
            t = tables[op]
            self.flat.append([(t.tf[c], t.st[c], t.g[c] + bf * t.ws)
                              for c in range(len(t.tf))])
        self.class_bws = [
            bf * tables[pre.order[pre.class_start[k]]].ws
            for k in range(pre.n_classes())
        ]
        self.suffix_min_trans = [0.0] * (n + 1)
        self.suffix_opt0_trans = [0.0] * (n + 1)
        for i in reversed(range(n)):
            t = tables[pre.order[i]]
            bws = bf * t.ws
            self.suffix_min_trans[i] = max(self.suffix_min_trans[i + 1],
                                           min(t.g) + bws)
            self.suffix_opt0_trans[i] = max(self.suffix_opt0_trans[i + 1],
                                            t.g[0] + bws)
        self.base_time = base_time(tables, b)
        self.base_act = sum(bf * t.act for t in tables)
        seed = greedy(tables, limit, b)
        if seed is None:
            self.seed = None
        else:
            ordered = [seed[op] for op in pre.order]
            tf = 0.0
            for i, c in enumerate(ordered):
                tf += self.flat[i][c][0]
            self.seed = (self.base_time + tf, ordered)

    def n(self):
        return self.pre.n()

    def unpermute(self, ordered):
        choice = [0] * len(ordered)
        for pos, op in enumerate(self.pre.order):
            choice[op] = ordered[pos]
        return choice


# ---------------------------------------------------------------- walker


def lex_less(a, b):
    for x, y in zip(a, b):
        if x != y:
            return x < y
    return False


class Walker:
    def __init__(self, space, frontiers=None):
        self.sp = space
        self.fr = frontiers
        if space.seed is None:
            self.best_time, self.best = float("inf"), None
        else:
            self.best_time, self.best = space.seed[0], list(space.seed[1])
        self.prefix = [0] * space.n()
        self.nodes = 0

    def open_subtree(self, i, tf, st, tm):
        sp = self.sp
        lb = sp.base_time + tf + sp.pre.suffix_min_time[i]
        if lb > self.best_time or (lb == self.best_time
                                   and not self.zero_beats_best(i)):
            return False
        peak = (st + sp.pre.suffix_min_states[i] + sp.base_act
                + max(tm, sp.suffix_min_trans[i]))
        return peak <= sp.limit

    def zero_beats_best(self, i):
        if self.best is None:
            return True
        for j in range(i):
            if self.prefix[j] != self.best[j]:
                return self.prefix[j] < self.best[j]
        return any(c > 0 for c in self.best[i:])

    def fast_completion(self, i, tf, st, tm):
        sp = self.sp
        peak = (st + sp.pre.suffix_opt0_states[i] + sp.base_act
                + max(tm, sp.suffix_opt0_trans[i]))
        if peak > sp.limit:
            return False
        for j in range(i, sp.n()):
            self.prefix[j] = 0
        self.accept(sp.base_time + tf + sp.pre.suffix_min_time[i])
        return True

    def accept(self, total):
        better = total < self.best_time or (
            total == self.best_time
            and (self.best is None or lex_less(self.prefix, self.best)))
        if better:
            self.best_time = total
            self.best = list(self.prefix)

    def descend_folded(self, k, tf, st, tm):
        self.nodes += 1
        i = self.sp.pre.class_start[k]
        if not self.open_subtree(i, tf, st, tm):
            return
        if i == self.sp.n():
            self.accept(self.sp.base_time + tf)
            return
        if self.fast_completion(i, tf, st, tm):
            return
        end = self.sp.pre.class_start[k + 1]
        o = len(self.sp.flat[i])
        block = [0] * (end - i)
        while True:
            btf, bst, btm = tf, st, tm
            for j, c in enumerate(block):
                opt = self.sp.flat[i + j][c]
                btf += opt[0]
                bst += opt[1]
                btm = max(btm, opt[2])
                self.prefix[i + j] = c
            self.descend_folded(k + 1, btf, bst, btm)
            if not next_monotone_block(block, o):
                break

    def descend_frontier(self, k, tf, st, tm):
        self.nodes += 1
        i = self.sp.pre.class_start[k]
        if not self.open_subtree(i, tf, st, tm):
            return
        if i == self.sp.n():
            self.accept(self.sp.base_time + tf)
            return
        if self.fast_completion(i, tf, st, tm):
            return
        cls = self.fr[k]
        if cls is not None:
            bws = self.sp.class_bws[k]
            for ptf, pst, pg, block in cls:
                for j, c in enumerate(block):
                    self.prefix[i + j] = c
                self.descend_frontier(k + 1, tf + ptf, st + pst,
                                      max(tm, pg + bws))
        else:  # too-wide fallback: enumerate blocks in place
            end = self.sp.pre.class_start[k + 1]
            o = len(self.sp.flat[i])
            block = [0] * (end - i)
            while True:
                btf, bst, btm = tf, st, tm
                for j, c in enumerate(block):
                    opt = self.sp.flat[i + j][c]
                    btf += opt[0]
                    bst += opt[1]
                    btm = max(btm, opt[2])
                    self.prefix[i + j] = c
                self.descend_frontier(k + 1, btf, bst, btm)
                if not next_monotone_block(block, o):
                    break


def run_split_frontier(tables, limit, b, depth):
    """Mirror of parallel.rs: tasks = combinations of the first `depth`
    classes' frontier points, each walker run from its prefix, merged by
    (time, lex). Shared-bound pruning omitted (it never decides a tie)."""
    pre = Prefold(tables)
    fr = build_frontiers(pre, tables)
    depth = min(depth, next((k for k, c in enumerate(fr) if c is None),
                            pre.n_classes()))
    space = Space(pre, tables, limit, b)
    # enumerate tasks: odometer over per-class point indices
    tasks = []
    pidx = [0] * depth
    while True:
        prefix = []
        for k in range(depth):
            prefix.extend(fr[k][pidx[k]][3])
        tf = 0.0
        st = 0.0
        tm = 0.0
        for i, c in enumerate(prefix):
            opt = space.flat[i][c]
            tf += opt[0]
            st += opt[1]
            tm = max(tm, opt[2])
        tasks.append((list(prefix), tf, st, tm))
        k = depth
        adv = False
        while k > 0:
            k -= 1
            pidx[k] += 1
            if pidx[k] < len(fr[k]):
                adv = True
                break
            pidx[k] = 0
        if not adv:
            break
    best = None if space.seed is None else (space.seed[0],
                                            list(space.seed[1]))
    nodes = 0
    for prefix, tf, st, tm in tasks:
        w = Walker(space, fr)
        w.prefix[:len(prefix)] = prefix
        w.descend_frontier(depth, tf, st, tm)
        nodes += w.nodes
        if w.best is None:
            continue
        if (best is None or w.best_time < best[0]
                or (w.best_time == best[0] and lex_less(w.best, best[1]))):
            best = (w.best_time, list(w.best))
    if best is None:
        return None
    return best[0], space.unpermute(best[1]), nodes


def run_engine(tables, limit, b, engine, frontiers=None, pre=None):
    pre = pre or Prefold(tables)
    space = Space(pre, tables, limit, b)
    if engine == "frontier" and frontiers is None:
        frontiers = build_frontiers(pre, tables)
    w = Walker(space, frontiers)
    if engine == "folded":
        w.descend_folded(0, 0.0, 0.0, 0.0)
    else:
        w.descend_frontier(0, 0.0, 0.0, 0.0)
    if w.best is None:
        return None
    return w.best_time, space.unpermute(w.best), w.nodes


# -------------------------------------------------------------- frontier


def build_frontiers(pre, tables, cap=1 << 18, force_too_wide=()):
    out = []
    for k in range(pre.n_classes()):
        t = tables[pre.order[pre.class_start[k]]]
        m = pre.mult(k)
        o = len(t.tf)
        if k in force_too_wide:
            out.append(None)
            continue
        cand = []
        block = [0] * m
        while True:
            tf = 0.0
            st = 0.0
            g = 0.0
            for c in block:
                tf += t.tf[c]
                st += t.st[c]
                g = max(g, t.g[c])
            cand.append((tf, st, g, list(block)))
            if not next_monotone_block(block, o):
                break
        if len(cand) > cap:
            out.append(None)
            continue
        idx = sorted(range(len(cand)), key=lambda p: cand[p][0])
        stair = []  # (st, g) staircase

        def dominated(st_, g_):
            lo, hi = 0, len(stair)
            while lo < hi:
                mid = (lo + hi) // 2
                if stair[mid][0] <= st_:
                    lo = mid + 1
                else:
                    hi = mid
            return lo > 0 and stair[lo - 1][1] <= g_

        def insert(st_, g_):
            lo, hi = 0, len(stair)
            while lo < hi:
                mid = (lo + hi) // 2
                if stair[mid][0] < st_:
                    lo = mid + 1
                else:
                    hi = mid
            j = lo
            while j < len(stair) and stair[j][1] >= g_:
                j += 1
            stair[lo:j] = [(st_, g_)]

        kept = []
        for p in idx:
            tf, st, g, block_ = cand[p]
            if dominated(st, g):
                continue
            insert(st, g)
            kept.append((tf, st, g, block_))
        out.append(kept)
    return out


# ------------------------------------------------------------ exhaustive


def brute_product(tables, limit, b):
    """Raw product space, canonical (total, lex-in-visit-order)."""
    pre = Prefold(tables)
    n = len(tables)
    best = None
    for choice in product(*[range(len(t.tf)) for t in tables]):
        ordered = [choice[op] for op in pre.order]
        _, peak = evaluate(tables, choice, b)
        if peak > limit:
            continue
        total = total_of(tables, pre.order, ordered, b)
        if (best is None or total < best[0]
                or (total == best[0] and lex_less(ordered, best[1]))):
            best = (total, ordered, list(choice))
    return None if best is None else (best[0], best[2])


def exhaustive_folded(tables, limit, b):
    """Monotone-block enumeration, canonical (total, lex)."""
    pre = Prefold(tables)
    n = pre.n()
    ordered = [0] * n
    best = None
    while True:
        choice = [0] * n
        for pos, op in enumerate(pre.order):
            choice[op] = ordered[pos]
        _, peak = evaluate(tables, choice, b)
        if peak <= limit:
            total = total_of(tables, pre.order, ordered, b)
            if (best is None or total < best[0]
                    or (total == best[0] and lex_less(ordered, best[1]))):
                best = (total, list(ordered), choice)
        k = pre.n_classes()
        advanced = False
        while k > 0:
            k -= 1
            s, e = pre.class_start[k], pre.class_start[k + 1]
            o = len(tables[pre.order[s]].tf)
            seg = ordered[s:e]
            if next_monotone_block(seg, o):
                ordered[s:e] = seg
                advanced = True
                break
            ordered[s:e] = [0] * (e - s)
        if not advanced:
            return None if best is None else (best[0], best[2])


# -------------------------------------------------------------- fixtures


def rand_instance(rng, max_classes=4, max_mult=4, max_opts=3):
    tables = []
    n_classes = rng.randint(1, max_classes)
    for _ in range(n_classes):
        mult = rng.randint(1, max_mult)
        o = rng.randint(1, max_opts)
        tf = sorted(rng.sample(range(1, 4000), o))
        tf = [v * TIME_GRID * 1000 for v in tf]
        st = [float(rng.randint(1, 400)) for _ in range(o)]
        g = [float(rng.randint(0, 300)) for _ in range(o)]
        act = rng.randint(0, 40)
        ws = rng.randint(0, 30)
        gamma = rng.randint(1, 100) * 1e-6
        proto = (tf, st, g, act, ws, gamma)
        for _ in range(mult):
            tables.append(Table(*proto))
    rng.shuffle(tables)
    return tables


def check(cond, msg, ctx):
    if not cond:
        print("FAIL:", msg)
        print("  ctx:", ctx)
        sys.exit(1)


def main():
    rng = random.Random(0xF807)
    full = 0
    for trial in range(400):
        tables = rand_instance(rng)
        b = rng.randint(1, 6)
        dp_peak = evaluate(tables, [0] * len(tables), b)[1]
        limit = dp_peak * (0.2 + rng.random() * 1.2)
        ctx = f"trial {trial} b={b} limit={limit}"

        brute = brute_product(tables, limit, b)
        folded = run_engine(tables, limit, b, "folded")
        front = run_engine(tables, limit, b, "frontier")
        exf = exhaustive_folded(tables, limit, b)

        if brute is None:
            check(folded is None and front is None and exf is None,
                  "feasibility disagreement (infeasible)", ctx)
            continue
        full += 1
        check(folded is not None, "folded lost feasibility", ctx)
        check(front is not None, "frontier lost feasibility", ctx)
        bt, bc = brute
        check(folded[0] == bt and folded[1] == bc,
              f"folded != brute: {folded[:2]} vs {brute}", ctx)
        check(front[0] == bt and front[1] == bc,
              f"frontier != brute: {front[:2]} vs {brute}", ctx)
        check(front[2] <= folded[2],
              f"frontier nodes {front[2]} > folded {folded[2]}", ctx)
        check(exf is not None and exf[0] == bt and exf[1] == bc,
              f"exhaustive_folded != brute: {exf} vs {brute}", ctx)

        # forced too-wide fallback on a random class
        pre = Prefold(tables)
        wide = rng.randrange(pre.n_classes())
        fr = build_frontiers(pre, tables, force_too_wide={wide})
        fb = run_engine(tables, limit, b, "frontier", frontiers=fr, pre=pre)
        check(fb is not None and fb[0] == bt and fb[1] == bc,
              f"fallback engine != brute: {fb} vs {brute}", ctx)

        # parallel split over frontier points, at several depths
        for depth in (0, 1, 2, 5):
            ps = run_split_frontier(tables, limit, b, depth)
            check(ps is not None and ps[0] == bt and ps[1] == bc,
                  f"split(depth={depth}) != brute: "
                  f"{ps and ps[:2]} vs {brute}", ctx)

    print(f"random instances: {full} full comparisons "
          f"(of 400 trials) all bit-exact")

    # batch-invariance: one frontier build across a sweep
    rng2 = random.Random(7)
    for trial in range(40):
        tables = rand_instance(rng2, max_classes=3, max_mult=5)
        pre = Prefold(tables)
        fr = build_frontiers(pre, tables)
        dp_peak = evaluate(tables, [0] * len(tables), 1)[1]
        limit = dp_peak * (0.4 + rng2.random() * 2.0)
        for b in range(1, 9):
            shared = run_engine(tables, limit, b, "frontier",
                                frontiers=fr, pre=pre)
            fresh = run_engine(tables, limit, b, "frontier")
            folded = run_engine(tables, limit, b, "folded")
            ctx = f"sweep trial {trial} b={b}"
            check(shared == fresh, "shared frontier != fresh build", ctx)
            if folded is None:
                check(shared is None, "sweep feasibility disagreement", ctx)
            else:
                check(shared is not None
                      and shared[:2] == folded[:2], "sweep mismatch", ctx)
    print("batch sweeps: shared frontier build bit-identical to fresh "
          "builds and to folded B&B at every batch size")

    # 24-layer-style instance: 2 big classes (m=24, o=2) + 2 singletons,
    # mirroring the paper-granularity deep uniform GPT
    grid = lambda v: v * TIME_GRID * 1000
    big_a = (
        [grid(10), grid(35)], [4000.0, 500.0], [0.0, 3500.0], 64, 16, 2e-5)
    big_b = (
        [grid(8), grid(30)], [3000.0, 380.0], [0.0, 2600.0], 48, 12, 1.5e-5)
    emb = ([grid(4), grid(18)], [9000.0, 1200.0], [0.0, 7800.0], 8, 4, 1e-5)
    head = ([grid(5), grid(20)], [9000.0, 1150.0], [0.0, 7900.0], 8, 4, 1e-5)
    tables = ([Table(*big_a) for _ in range(24)]
              + [Table(*big_b) for _ in range(24)]
              + [Table(*emb), Table(*head)])
    pre = Prefold(tables)
    fr = build_frontiers(pre, tables)
    pts = sum(len(c) for c in fr)
    comp = sum(25 for _ in range(2)) + 4
    print(f"24L-style: {comp} compositions -> {pts} frontier points; "
          f"per-class {[len(c) for c in fr]}")
    dp_peak = evaluate(tables, [0] * len(tables), 1)[1]
    zdp_peak = evaluate(tables, [len(t.tf) - 1 for t in tables], 1)[1]
    rows = []
    for b in range(1, 9):
        limit = zdp_peak * b * 0.2 + dp_peak * 0.55
        folded = run_engine(tables, limit, b, "folded")
        front = run_engine(tables, limit, b, "frontier", frontiers=fr,
                           pre=pre)
        if folded is None:
            check(front is None, "24L feasibility disagreement", b)
            continue
        check(front[:2] == folded[:2], "24L mismatch", b)
        check(front[2] <= folded[2], "24L frontier explored more", b)
        rows.append((b, folded[2], front[2]))
    print("24L-style per-batch nodes (b, folded, frontier):", rows)
    print("OK: all mirror checks passed")


if __name__ == "__main__":
    main()
