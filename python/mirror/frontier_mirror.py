"""Python mirror of the Rust planner's frontier engine (PR 3 + PR 9).

Mirrors, operation-for-operation in IEEE-754 doubles:

* ``planner/bound.rs``  — Prefold order, suffix bounds, the folded
  branch-and-bound Walker (greedy seed pricing, strict/tie time pruning,
  memory pruning, fast completion);
* ``planner/frontier.rs`` — the per-class **incremental Minkowski-sum**
  frontier build (level-by-level (time, lex-block) processing + 2-D
  staircase prune, no width ceiling) and the frontier descent;
* ``planner/exhaustive.rs`` — the folded (time, lex) ground-truth
  enumerator.

Checks, on hundreds of random instances x batch sizes x memory limits:

1. folded B&B  == brute force over the raw product space, bit-for-bit
   (total time bits AND full choice vector — the canonical (total, lex)
   objective);
2. frontier    == folded B&B, bit-for-bit, with node count <= folded's;
3. the incremental build == the retired one-shot enumeration, point for
   point and bit for bit (aggregates AND blocks), on every class of
   every instance — the strongest oracle: the per-level prune must keep
   exactly the one-shot kept set, in the same (tf, lex) order;
4. folded exhaustive == brute force, bit-for-bit;
5. one shared frontier build serves a whole batch sweep (batch
   invariance): per-batch results equal fresh builds at every b;
6. the parallel split over the leading classes' frontier points
   (``enumerate_tasks_frontier`` + the deterministic (time, lex) merge)
   equals the serial frontier engine at every split depth;
7. wide classes **above the old 2^18 one-shot ceiling** (o=4, m=96 and
   m=116): incremental == one-shot oracle == folded B&B ==
   exhaustive-folded, full choice vectors, serial and split;
8. the 96L/1000L-style bench ladder builds with bounded per-level
   widths (printed, to calibrate OSDP_BENCH_STRICT floors) and the 96L
   frontier sweep visits no more nodes than the folded engine.

Run: ``python3 python/mirror/frontier_mirror.py`` (exits non-zero on any
mismatch; prints node-count and width evidence for the ladder).
"""

import random
import sys
from itertools import product

TIME_GRID = 1.0 / (1 << 30)


def snap(t):
    # exact for grid multiples; synthetic menus only use grid multiples
    return round(t * (1 << 30)) * TIME_GRID


def grid(v):
    return v * TIME_GRID * 1000


# ----------------------------------------------------------------- model


class Table:
    def __init__(self, tf, st, g, act, ws, gamma):
        # menus sorted fastest-first, like cost/menu.rs emits
        order = sorted(range(len(tf)), key=lambda i: tf[i])
        self.tf = [tf[i] for i in order]
        self.st = [float(st[i]) for i in order]
        self.g = [float(g[i]) for i in order]
        self.act = float(act)
        self.ws = float(ws)
        self.gamma = gamma

    def key(self):
        return (self.act, self.ws, self.gamma, tuple(self.tf),
                tuple(self.st), tuple(self.g))


def batch_eff(b):
    return b / (b + 2.0)


def base_time(tables, b):
    compute = sum(b * t.gamma for t in tables)
    return snap(compute / batch_eff(b))


def evaluate(tables, choice, b):
    """profiler.evaluate mirror: (time, peak)."""
    tf = 0.0
    compute = 0.0
    persistent = 0.0
    trans = 0.0
    for t, c in zip(tables, choice):
        tf += t.tf[c]
        compute += b * t.gamma
        persistent += t.st[c] + b * t.act
        trans = max(trans, t.g[c] + b * t.ws)
    return tf + compute / batch_eff(b), persistent + trans


def total_of(tables, order, ordered, b):
    """Search-arithmetic total: base + grid tf sum in visit order."""
    tf = 0.0
    for pos, c in enumerate(ordered):
        tf += tables[order[pos]].tf[c]
    return base_time(tables, b) + tf


# --------------------------------------------------------------- prefold


class Prefold:
    def __init__(self, tables):
        n = len(tables)
        base = sorted(range(n), key=lambda i: -tables[i].st[0])
        # stable sort: ties keep profiler order (python sort is stable)
        keys = {}
        cid = []
        for i in range(n):
            k = tables[i].key()
            cid.append(keys.setdefault(k, len(keys)))
        members = [[] for _ in keys]
        for op in base:
            members[cid[op]].append(op)
        self.order = []
        self.class_start = []
        placed = [False] * len(keys)
        for op in base:
            c = cid[op]
            if not placed[c]:
                placed[c] = True
                self.class_start.append(len(self.order))
                self.order.extend(members[c])
        self.class_start.append(n)
        self.suffix_min_time = [0.0] * (n + 1)
        self.suffix_min_states = [0.0] * (n + 1)
        self.suffix_opt0_states = [0.0] * (n + 1)
        for i in reversed(range(n)):
            t = tables[self.order[i]]
            self.suffix_min_time[i] = self.suffix_min_time[i + 1] + t.tf[0]
            self.suffix_min_states[i] = (self.suffix_min_states[i + 1]
                                         + min(t.st))
            self.suffix_opt0_states[i] = (self.suffix_opt0_states[i + 1]
                                          + t.st[0])

    def n(self):
        return len(self.order)

    def n_classes(self):
        return len(self.class_start) - 1

    def mult(self, k):
        return self.class_start[k + 1] - self.class_start[k]


def next_monotone_block(block, o):
    for p in reversed(range(len(block))):
        if block[p] + 1 < o:
            v = block[p] + 1
            for q in range(p, len(block)):
                block[q] = v
            return True
    return False


# ---------------------------------------------------------------- greedy


def greedy(tables, limit, b):
    n = len(tables)
    choice = [0] * n
    _, peak = evaluate(tables, choice, b)
    while peak > limit:
        best = None
        for i in range(n):
            t = tables[i]
            cur = choice[i]
            for c in range(cur + 1, len(t.tf)):
                dmem = (t.st[cur] - t.st[c]) + max(t.g[cur] - t.g[c], 0.0)
                dtime = t.tf[c] - t.tf[cur]
                if dmem <= 0.0:
                    continue
                ratio = dmem / max(dtime, 1e-15)
                if best is None or ratio > best[2]:
                    best = (i, c, ratio)
        if best is None:
            return None
        choice[best[0]] = best[1]
        _, peak = evaluate(tables, choice, b)
    return choice


# ---------------------------------------------------------------- spaces


class Space:
    def __init__(self, pre, tables, limit, b):
        self.pre = pre
        self.tables = tables
        self.limit = limit
        n = pre.n()
        bf = float(b)
        self.flat = []
        for op in pre.order:
            t = tables[op]
            self.flat.append([(t.tf[c], t.st[c], t.g[c] + bf * t.ws)
                              for c in range(len(t.tf))])
        self.class_bws = [
            bf * tables[pre.order[pre.class_start[k]]].ws
            for k in range(pre.n_classes())
        ]
        self.suffix_min_trans = [0.0] * (n + 1)
        self.suffix_opt0_trans = [0.0] * (n + 1)
        for i in reversed(range(n)):
            t = tables[pre.order[i]]
            bws = bf * t.ws
            self.suffix_min_trans[i] = max(self.suffix_min_trans[i + 1],
                                           min(t.g) + bws)
            self.suffix_opt0_trans[i] = max(self.suffix_opt0_trans[i + 1],
                                            t.g[0] + bws)
        self.base_time = base_time(tables, b)
        self.base_act = sum(bf * t.act for t in tables)
        seed = greedy(tables, limit, b)
        if seed is None:
            self.seed = None
        else:
            ordered = [seed[op] for op in pre.order]
            tf = 0.0
            for i, c in enumerate(ordered):
                tf += self.flat[i][c][0]
            self.seed = (self.base_time + tf, ordered)

    def n(self):
        return self.pre.n()

    def unpermute(self, ordered):
        choice = [0] * len(ordered)
        for pos, op in enumerate(self.pre.order):
            choice[op] = ordered[pos]
        return choice


# ---------------------------------------------------------------- walker


def lex_less(a, b):
    for x, y in zip(a, b):
        if x != y:
            return x < y
    return False


class Walker:
    def __init__(self, space, frontiers=None):
        self.sp = space
        self.fr = frontiers
        if space.seed is None:
            self.best_time, self.best = float("inf"), None
        else:
            self.best_time, self.best = space.seed[0], list(space.seed[1])
        self.prefix = [0] * space.n()
        self.nodes = 0

    def open_subtree(self, i, tf, st, tm):
        sp = self.sp
        lb = sp.base_time + tf + sp.pre.suffix_min_time[i]
        if lb > self.best_time or (lb == self.best_time
                                   and not self.zero_beats_best(i)):
            return False
        peak = (st + sp.pre.suffix_min_states[i] + sp.base_act
                + max(tm, sp.suffix_min_trans[i]))
        return peak <= sp.limit

    def zero_beats_best(self, i):
        if self.best is None:
            return True
        for j in range(i):
            if self.prefix[j] != self.best[j]:
                return self.prefix[j] < self.best[j]
        return any(c > 0 for c in self.best[i:])

    def fast_completion(self, i, tf, st, tm):
        sp = self.sp
        peak = (st + sp.pre.suffix_opt0_states[i] + sp.base_act
                + max(tm, sp.suffix_opt0_trans[i]))
        if peak > sp.limit:
            return False
        for j in range(i, sp.n()):
            self.prefix[j] = 0
        self.accept(sp.base_time + tf + sp.pre.suffix_min_time[i])
        return True

    def accept(self, total):
        better = total < self.best_time or (
            total == self.best_time
            and (self.best is None or lex_less(self.prefix, self.best)))
        if better:
            self.best_time = total
            self.best = list(self.prefix)

    def descend_folded(self, k, tf, st, tm):
        self.nodes += 1
        i = self.sp.pre.class_start[k]
        if not self.open_subtree(i, tf, st, tm):
            return
        if i == self.sp.n():
            self.accept(self.sp.base_time + tf)
            return
        if self.fast_completion(i, tf, st, tm):
            return
        end = self.sp.pre.class_start[k + 1]
        o = len(self.sp.flat[i])
        block = [0] * (end - i)
        while True:
            btf, bst, btm = tf, st, tm
            for j, c in enumerate(block):
                opt = self.sp.flat[i + j][c]
                btf += opt[0]
                bst += opt[1]
                btm = max(btm, opt[2])
                self.prefix[i + j] = c
            self.descend_folded(k + 1, btf, bst, btm)
            if not next_monotone_block(block, o):
                break

    def descend_frontier(self, k, tf, st, tm):
        self.nodes += 1
        i = self.sp.pre.class_start[k]
        if not self.open_subtree(i, tf, st, tm):
            return
        if i == self.sp.n():
            self.accept(self.sp.base_time + tf)
            return
        if self.fast_completion(i, tf, st, tm):
            return
        cls = self.fr[k]
        bws = self.sp.class_bws[k]
        for ptf, pst, pg, block in cls:
            for j, c in enumerate(block):
                self.prefix[i + j] = c
            self.descend_frontier(k + 1, tf + ptf, st + pst,
                                  max(tm, pg + bws))


def run_split_frontier(tables, limit, b, depth, fr=None):
    """Mirror of parallel.rs: tasks = combinations of the first `depth`
    classes' frontier points, each walker run from its prefix, merged by
    (time, lex). Shared-bound pruning omitted (it never decides a tie)."""
    pre = Prefold(tables)
    if fr is None:
        fr = build_frontiers(pre, tables)
    depth = min(depth, pre.n_classes())
    space = Space(pre, tables, limit, b)
    # enumerate tasks: odometer over per-class point indices
    tasks = []
    pidx = [0] * depth
    while True:
        prefix = []
        for k in range(depth):
            prefix.extend(fr[k][pidx[k]][3])
        tf = 0.0
        st = 0.0
        tm = 0.0
        for i, c in enumerate(prefix):
            opt = space.flat[i][c]
            tf += opt[0]
            st += opt[1]
            tm = max(tm, opt[2])
        tasks.append((list(prefix), tf, st, tm))
        k = depth
        adv = False
        while k > 0:
            k -= 1
            pidx[k] += 1
            if pidx[k] < len(fr[k]):
                adv = True
                break
            pidx[k] = 0
        if not adv:
            break
    best = None if space.seed is None else (space.seed[0],
                                            list(space.seed[1]))
    nodes = 0
    for prefix, tf, st, tm in tasks:
        w = Walker(space, fr)
        w.prefix[:len(prefix)] = prefix
        w.descend_frontier(depth, tf, st, tm)
        nodes += w.nodes
        if w.best is None:
            continue
        if (best is None or w.best_time < best[0]
                or (w.best_time == best[0] and lex_less(w.best, best[1]))):
            best = (w.best_time, list(w.best))
    if best is None:
        return None
    return best[0], space.unpermute(best[1]), nodes


def run_engine(tables, limit, b, engine, frontiers=None, pre=None):
    pre = pre or Prefold(tables)
    space = Space(pre, tables, limit, b)
    if engine == "frontier" and frontiers is None:
        frontiers = build_frontiers(pre, tables)
    w = Walker(space, frontiers)
    if engine == "folded":
        w.descend_folded(0, 0.0, 0.0, 0.0)
    else:
        w.descend_frontier(0, 0.0, 0.0, 0.0)
    if w.best is None:
        return None
    return w.best_time, space.unpermute(w.best), w.nodes


# -------------------------------------------------------------- frontier


class Stair:
    """(states, gather) staircase: states ascending, gather strictly
    descending (stair_dominates / stair_insert in frontier.rs)."""

    def __init__(self):
        self.s = []

    def dominated(self, st, g):
        lo, hi = 0, len(self.s)
        while lo < hi:
            mid = (lo + hi) // 2
            if self.s[mid][0] <= st:
                lo = mid + 1
            else:
                hi = mid
        return lo > 0 and self.s[lo - 1][1] <= g

    def insert(self, st, g):
        lo, hi = 0, len(self.s)
        while lo < hi:
            mid = (lo + hi) // 2
            if self.s[mid][0] < st:
                lo = mid + 1
            else:
                hi = mid
        j = lo
        while j < len(self.s) and self.s[j][1] >= g:
            j += 1
        self.s[lo:j] = [(st, g)]


def build_class(t, m):
    """Incremental Minkowski-sum build, mirroring ``build_class`` in
    ``frontier.rs``: the level-``l`` frontier is the staircase-pruned sum
    of the level-``l-1`` frontier with the class menu. Candidates are
    processed in (tf, lex-block) order; blocks are tracked as option
    counts, and counts compare *descending* because putting more members
    on a smaller option is the lex-smaller block. Returns
    ``(kept points with materialized blocks, peak level width)``."""
    o = len(t.tf)
    pts = [(0.0, 0.0, 0.0, (0,) * o)]  # level 0: the empty block
    peak = 1
    for _level in range(m):
        cand = []
        for tf, st, g, counts in pts:
            for c in range(o):
                nc = list(counts)
                nc[c] += 1
                cand.append((tf + t.tf[c], st + t.st[c],
                             max(g, t.g[c]), tuple(nc)))
        cand.sort(key=lambda e: (e[0], tuple(-x for x in e[3])))
        stair = Stair()
        kept = []
        for tf, st, g, counts in cand:
            if stair.dominated(st, g):
                continue
            stair.insert(st, g)
            kept.append((tf, st, g, counts))
        pts = kept
        peak = max(peak, len(pts))
    out = []
    for tf, st, g, counts in pts:
        block = []
        for c, n in enumerate(counts):
            block.extend([c] * n)
        out.append((tf, st, g, block))
    return out, peak


def build_class_oneshot(t, m):
    """The retired one-shot enumeration (PR 3 — kept as the oracle):
    every monotone block, (time, lex) stable sort, staircase prune."""
    o = len(t.tf)
    cand = []
    block = [0] * m
    while True:
        tf = 0.0
        st = 0.0
        g = 0.0
        for c in block:
            tf += t.tf[c]
            st += t.st[c]
            g = max(g, t.g[c])
        cand.append((tf, st, g, list(block)))
        if not next_monotone_block(block, o):
            break
    idx = sorted(range(len(cand)), key=lambda p: cand[p][0])
    stair = Stair()
    kept = []
    for p in idx:
        tf, st, g, block_ = cand[p]
        if stair.dominated(st, g):
            continue
        stair.insert(st, g)
        kept.append((tf, st, g, block_))
    return kept


def build_frontiers(pre, tables):
    out = []
    for k in range(pre.n_classes()):
        t = tables[pre.order[pre.class_start[k]]]
        kept, _peak = build_class(t, pre.mult(k))
        out.append(kept)
    return out


def check_build_matches_oneshot(pre, tables, ctx):
    """Oracle: the incremental kept set equals the one-shot kept set —
    same points, same (tf, lex) order, same bits."""
    for k in range(pre.n_classes()):
        t = tables[pre.order[pre.class_start[k]]]
        inc, _ = build_class(t, pre.mult(k))
        one = build_class_oneshot(t, pre.mult(k))
        check(len(inc) == len(one),
              f"class {k}: incremental {len(inc)} pts != "
              f"one-shot {len(one)}", ctx)
        for a, b in zip(inc, one):
            check(a[3] == b[3]
                  and all(x.hex() == y.hex()
                          for x, y in zip(a[:3], b[:3])),
                  f"class {k}: incremental point != one-shot: "
                  f"{a} vs {b}", ctx)


# ------------------------------------------------------------ exhaustive


def brute_product(tables, limit, b):
    """Raw product space, canonical (total, lex-in-visit-order)."""
    pre = Prefold(tables)
    n = len(tables)
    best = None
    for choice in product(*[range(len(t.tf)) for t in tables]):
        ordered = [choice[op] for op in pre.order]
        _, peak = evaluate(tables, choice, b)
        if peak > limit:
            continue
        total = total_of(tables, pre.order, ordered, b)
        if (best is None or total < best[0]
                or (total == best[0] and lex_less(ordered, best[1]))):
            best = (total, ordered, list(choice))
    return None if best is None else (best[0], best[2])


def exhaustive_folded(tables, limit, b):
    """Monotone-block enumeration, canonical (total, lex)."""
    pre = Prefold(tables)
    n = pre.n()
    ordered = [0] * n
    best = None
    while True:
        choice = [0] * n
        for pos, op in enumerate(pre.order):
            choice[op] = ordered[pos]
        _, peak = evaluate(tables, choice, b)
        if peak <= limit:
            total = total_of(tables, pre.order, ordered, b)
            if (best is None or total < best[0]
                    or (total == best[0] and lex_less(ordered, best[1]))):
                best = (total, list(ordered), choice)
        k = pre.n_classes()
        advanced = False
        while k > 0:
            k -= 1
            s, e = pre.class_start[k], pre.class_start[k + 1]
            o = len(tables[pre.order[s]].tf)
            seg = ordered[s:e]
            if next_monotone_block(seg, o):
                ordered[s:e] = seg
                advanced = True
                break
            ordered[s:e] = [0] * (e - s)
        if not advanced:
            return None if best is None else (best[0], best[2])


# -------------------------------------------------------------- fixtures


def rand_instance(rng, max_classes=4, max_mult=4, max_opts=3):
    tables = []
    n_classes = rng.randint(1, max_classes)
    for _ in range(n_classes):
        mult = rng.randint(1, max_mult)
        o = rng.randint(1, max_opts)
        tf = sorted(rng.sample(range(1, 4000), o))
        tf = [v * TIME_GRID * 1000 for v in tf]
        st = [float(rng.randint(1, 400)) for _ in range(o)]
        g = [float(rng.randint(0, 300)) for _ in range(o)]
        act = rng.randint(0, 40)
        ws = rng.randint(0, 30)
        gamma = rng.randint(1, 100) * 1e-6
        proto = (tf, st, g, act, ws, gamma)
        for _ in range(mult):
            tables.append(Table(*proto))
    rng.shuffle(tables)
    return tables


def check(cond, msg, ctx):
    if not cond:
        print("FAIL:", msg)
        print("  ctx:", ctx)
        sys.exit(1)


def main():
    rng = random.Random(0xF807)
    full = 0
    for trial in range(400):
        tables = rand_instance(rng)
        b = rng.randint(1, 6)
        dp_peak = evaluate(tables, [0] * len(tables), b)[1]
        limit = dp_peak * (0.2 + rng.random() * 1.2)
        ctx = f"trial {trial} b={b} limit={limit}"

        brute = brute_product(tables, limit, b)
        folded = run_engine(tables, limit, b, "folded")
        front = run_engine(tables, limit, b, "frontier")
        exf = exhaustive_folded(tables, limit, b)

        if brute is None:
            check(folded is None and front is None and exf is None,
                  "feasibility disagreement (infeasible)", ctx)
            continue
        full += 1
        check(folded is not None, "folded lost feasibility", ctx)
        check(front is not None, "frontier lost feasibility", ctx)
        bt, bc = brute
        check(folded[0] == bt and folded[1] == bc,
              f"folded != brute: {folded[:2]} vs {brute}", ctx)
        check(front[0] == bt and front[1] == bc,
              f"frontier != brute: {front[:2]} vs {brute}", ctx)
        check(front[2] <= folded[2],
              f"frontier nodes {front[2]} > folded {folded[2]}", ctx)
        check(exf is not None and exf[0] == bt and exf[1] == bc,
              f"exhaustive_folded != brute: {exf} vs {brute}", ctx)

        # incremental build == one-shot oracle, bit for bit, every class
        check_build_matches_oneshot(Prefold(tables), tables, ctx)

        # parallel split over frontier points, at several depths
        for depth in (0, 1, 2, 5):
            ps = run_split_frontier(tables, limit, b, depth)
            check(ps is not None and ps[0] == bt and ps[1] == bc,
                  f"split(depth={depth}) != brute: "
                  f"{ps and ps[:2]} vs {brute}", ctx)

    print(f"random instances: {full} full comparisons "
          f"(of 400 trials) all bit-exact")

    # batch-invariance: one frontier build across a sweep
    rng2 = random.Random(7)
    for trial in range(40):
        tables = rand_instance(rng2, max_classes=3, max_mult=5)
        pre = Prefold(tables)
        fr = build_frontiers(pre, tables)
        dp_peak = evaluate(tables, [0] * len(tables), 1)[1]
        limit = dp_peak * (0.4 + rng2.random() * 2.0)
        for b in range(1, 9):
            shared = run_engine(tables, limit, b, "frontier",
                                frontiers=fr, pre=pre)
            fresh = run_engine(tables, limit, b, "frontier")
            folded = run_engine(tables, limit, b, "folded")
            ctx = f"sweep trial {trial} b={b}"
            check(shared == fresh, "shared frontier != fresh build", ctx)
            if folded is None:
                check(shared is None, "sweep feasibility disagreement", ctx)
            else:
                check(shared is not None
                      and shared[:2] == folded[:2], "sweep mismatch", ctx)
    print("batch sweeps: shared frontier build bit-identical to fresh "
          "builds and to folded B&B at every batch size")

    # 24-layer-style instance: 2 big classes (m=24, o=2) + 2 singletons,
    # mirroring the paper-granularity deep uniform GPT
    big_a = (
        [grid(10), grid(35)], [4000.0, 500.0], [0.0, 3500.0], 64, 16, 2e-5)
    big_b = (
        [grid(8), grid(30)], [3000.0, 380.0], [0.0, 2600.0], 48, 12, 1.5e-5)
    emb = ([grid(4), grid(18)], [9000.0, 1200.0], [0.0, 7800.0], 8, 4, 1e-5)
    head = ([grid(5), grid(20)], [9000.0, 1150.0], [0.0, 7900.0], 8, 4, 1e-5)
    tables = ([Table(*big_a) for _ in range(24)]
              + [Table(*big_b) for _ in range(24)]
              + [Table(*emb), Table(*head)])
    pre = Prefold(tables)
    fr = build_frontiers(pre, tables)
    pts = sum(len(c) for c in fr)
    comp = sum(25 for _ in range(2)) + 4
    print(f"24L-style: {comp} compositions -> {pts} frontier points; "
          f"per-class {[len(c) for c in fr]}")
    dp_peak = evaluate(tables, [0] * len(tables), 1)[1]
    zdp_peak = evaluate(tables, [len(t.tf) - 1 for t in tables], 1)[1]
    rows = []
    for b in range(1, 9):
        limit = zdp_peak * b * 0.2 + dp_peak * 0.55
        folded = run_engine(tables, limit, b, "folded")
        front = run_engine(tables, limit, b, "frontier", frontiers=fr,
                           pre=pre)
        if folded is None:
            check(front is None, "24L feasibility disagreement", b)
            continue
        check(front[:2] == folded[:2], "24L mismatch", b)
        check(front[2] <= folded[2], "24L frontier explored more", b)
        rows.append((b, folded[2], front[2]))
    print("24L-style per-batch nodes (b, folded, frontier):", rows)

    # wide classes above the old 2^18 one-shot ceiling ------------------
    # A production-shaped 4-option menu (granularities {0,2,4,8}: states
    # shrink as fixed time grows; gather is non-monotone), grid-snapped.
    import math
    import time as clock

    wide = ([grid(10), grid(22), grid(33), grid(47)],
            [4000.0, 2600.0, 1100.0, 400.0],
            [0.0, 1500.0, 900.0, 2100.0], 64, 16, 2e-5)
    for m, limit_fracs, exhaustive_fracs in ((96, (0.45, 0.8), (0.45,)),
                                             (116, (0.45,), (0.45,))):
        tables = [Table(*wide) for _ in range(m)]
        pre = Prefold(tables)
        comp = math.comb(m + 3, 3)
        t0 = clock.monotonic()
        inc, peak = build_class(tables[pre.order[0]], m)
        dt = clock.monotonic() - t0
        above = "above" if comp > (1 << 18) else "below"
        print(f"wide class o=4 m={m}: {comp} compositions ({above} the "
              f"old 2^18 ceiling) -> {len(inc)} points, peak level "
              f"width {peak}, incremental build {dt:.2f}s python")
        one = build_class_oneshot(tables[pre.order[0]], m)
        check(len(inc) == len(one)
              and all(a[3] == ob[3]
                      and all(x.hex() == y.hex()
                              for x, y in zip(a[:3], ob[:3]))
                      for a, ob in zip(inc, one)),
              "wide incremental build != one-shot oracle", f"m={m}")
        fr = [inc]
        dp_peak = evaluate(tables, [0] * m, 2)[1]
        for frac in limit_fracs:
            limit = dp_peak * frac
            ctx = f"wide m={m} frac={frac}"
            front = run_engine(tables, limit, 2, "frontier",
                               frontiers=fr, pre=pre)
            folded = run_engine(tables, limit, 2, "folded")
            check(front is not None and folded is not None
                  and front[:2] == folded[:2],
                  f"wide frontier != folded: {front and front[:2]} vs "
                  f"{folded and folded[:2]}", ctx)
            check(front[2] <= folded[2],
                  f"wide frontier nodes {front[2]} > folded {folded[2]}",
                  ctx)
            # the split merge is the 8-thread analog: a genuinely
            # different traversal order over the same frontier
            ps = run_split_frontier(tables, limit, 2, 1, fr=fr)
            check(ps is not None and ps[:2] == front[:2],
                  "wide split != serial", ctx)
            if frac in exhaustive_fracs:
                exf = exhaustive_folded(tables, limit, 2)
                check(exf is not None and exf[0] == front[0]
                      and exf[1] == front[1],
                      f"wide exhaustive != frontier: {exf} vs "
                      f"{front[:2]}", ctx)
    print("wide classes: incremental == one-shot oracle == folded "
          "== exhaustive-folded, serial and split")

    # bench-ladder analogs: 96L / 1000L uniform stacks, wide menus ------
    def ladder_tables(layers):
        la = wide
        lb = ([grid(8), grid(19), grid(29), grid(41)],
              [3000.0, 1900.0, 800.0, 300.0],
              [0.0, 1100.0, 700.0, 1600.0], 48, 12, 1.5e-5)
        emb = ([grid(4), grid(18)], [9000.0, 1200.0], [0.0, 7800.0],
               8, 4, 1e-5)
        head = ([grid(5), grid(20)], [9000.0, 1150.0], [0.0, 7900.0],
                8, 4, 1e-5)
        return ([Table(*la) for _ in range(layers)]
                + [Table(*lb) for _ in range(layers)]
                + [Table(*emb), Table(*head)])

    def counts_of(block, o):
        return tuple(block.count(c) for c in range(o))

    def check_frontier_invariants(kept, m, ctx):
        """Cheap structural checks on a built class frontier: leads with
        the all-fastest block, (tf, lex)-sorted, mutually undominated in
        (states, gather) — so no point could ever shadow another."""
        check(kept[0][3] == [0] * m, "frontier does not lead with the "
              "pure block", ctx)
        check(all(kept[i][0] <= kept[i + 1][0]
                  for i in range(len(kept) - 1)),
              "frontier not sorted by time_fixed", ctx)
        for i in range(len(kept)):
            sti, gi = kept[i][1], kept[i][2]
            for j in range(i + 1, len(kept)):
                check(not (sti <= kept[j][1] and gi <= kept[j][2]),
                      f"kept point {i} dominates kept point {j}", ctx)

    def check_half_split(t, m, kept, ctx):
        """Independent deep-m oracle: the frontier at multiplicity m
        equals the staircase-pruned Minkowski sum of the frontiers at
        m-64 and 64. The module-docs exactness lemma (dominance and
        (tf, lex) precedence survive `⊕ c`) holds for *aggregate* c, not
        just single options — this exercises it where the one-shot
        enumeration (C(m+3, 3) compositions) is unreachable."""
        o = len(t.tf)
        fa, _ = build_class(t, m - 64)
        fb, _ = build_class(t, 64)
        ca = [(tf, st, g, counts_of(blk, o)) for tf, st, g, blk in fa]
        cb = [(tf, st, g, counts_of(blk, o)) for tf, st, g, blk in fb]
        cand = [(tfa + tfb, sta + stb, max(ga, gb),
                 tuple(x + y for x, y in zip(na, nb)))
                for tfa, sta, ga, na in ca
                for tfb, stb, gb, nb in cb]
        cand.sort(key=lambda e: (e[0], tuple(-x for x in e[3])))
        stair = Stair()
        merged = []
        for tf, st, g, counts in cand:
            if stair.dominated(st, g):
                continue
            stair.insert(st, g)
            merged.append((tf, st, g, counts))
        check(len(merged) == len(kept),
              f"half-split {len(merged)} pts != direct {len(kept)}", ctx)
        for p, q in zip(kept, merged):
            check(counts_of(p[3], o) == q[3]
                  and all(x.hex() == y.hex()
                          for x, y in zip(p[:3], q[:3])),
                  f"half-split point != direct: {q} vs {p[:3]}", ctx)

    # folded has no node budget here, so it only runs on the 12L rung
    # (two wide classes of C(15,3)=455 compositions — tractable); the
    # 96L rung relies on the single-wide-class folded/exhaustive
    # identities proven above and checks frontier vs split only. The
    # 1000L rung runs no Python searches at all — the unbudgeted walker's
    # per-node cost scales with the ~3000-point class width here (the
    # Rust bench runs the actual 1000L sweep, whose DFS is hard-capped by
    # the ~36M distinct prefixes) — and instead validates the deep build
    # itself: structural invariants plus the half-split identity.
    for layers, batches, folded_bs in ((12, (1, 2, 4, 8), (1, 4)),
                                       (96, (1, 2, 4, 8), ()),
                                       (1000, (), ())):
        tables = ladder_tables(layers)
        pre = Prefold(tables)
        fr = []
        peaks = []
        t0 = clock.monotonic()
        for k in range(pre.n_classes()):
            t = tables[pre.order[pre.class_start[k]]]
            kp, pk = build_class(t, pre.mult(k))
            fr.append(kp)
            peaks.append(pk)
        dt = clock.monotonic() - t0
        print(f"{layers}L-style: per-class points {[len(c) for c in fr]}"
              f", peak level widths {peaks}, build {dt:.2f}s python")
        if not batches:
            t0 = clock.monotonic()
            for k in range(pre.n_classes()):
                m = pre.mult(k)
                ctx = f"{layers}L class {k} (m={m})"
                check_frontier_invariants(fr[k], m, ctx)
                if m > 64:
                    t = tables[pre.order[pre.class_start[k]]]
                    check_half_split(t, m, fr[k], ctx)
            print(f"{layers}L-style: invariants + half-split identity "
                  f"(frontier(m) == pruned frontier(m-64) ⊕ frontier(64))"
                  f" on every class, {clock.monotonic() - t0:.1f}s")
            continue
        dp_peak = evaluate(tables, [0] * len(tables), 1)[1]
        zdp_peak = evaluate(tables, [len(t.tf) - 1 for t in tables],
                            1)[1]
        rows = []
        for b in batches:
            limit = zdp_peak * b * 0.2 + dp_peak * 0.55
            ctx = f"{layers}L b={b}"
            front = run_engine(tables, limit, b, "frontier",
                               frontiers=fr, pre=pre)
            check(front is not None, "ladder sweep infeasible", ctx)
            nodes_folded = None
            if b in folded_bs:
                folded = run_engine(tables, limit, b, "folded")
                check(folded is not None and front[:2] == folded[:2],
                      "ladder frontier != folded", ctx)
                check(front[2] <= folded[2],
                      f"ladder frontier nodes {front[2]} > folded "
                      f"{folded[2]}", ctx)
                nodes_folded = folded[2]
            ps = run_split_frontier(tables, limit, b, 1, fr=fr)
            check(ps is not None and ps[:2] == front[:2],
                  "ladder split != serial", ctx)
            rows.append((b, front[2], nodes_folded))
        print(f"{layers}L-style per-batch (b, frontier nodes, folded "
              f"nodes or None): {rows}")

    print("OK: all mirror checks passed")


if __name__ == "__main__":
    main()
