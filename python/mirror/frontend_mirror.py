"""Python mirror of the socket front-end's concurrency machinery (PR 6,
in the tradition of service_mirror.py — this container has no Rust
toolchain, so the load-bearing concurrent-systems design is re-validated
here with real threads and real sockets).

Mirrors:

* ``service/frontend.rs::Channel`` — the bounded MPMC handoff between
  the acceptor and the worker pool: FIFO, blocking ``send`` at capacity,
  ``close()`` lets receivers drain queued items then observe the end;
* ``service/frontend.rs::read_request_line`` — newline framing with the
  16 KiB line cap, idle-timeout accounting, and the
  structured-error-then-hangup paths;
* ``service/telemetry.rs`` — the fixed bucket bounds, the binning rule
  (first bound the latency does not exceed), and the bucket-resolution
  quantile estimate;
* the service's single-flight coalescing contract, driven through TCP
  this time: N identical concurrent queries -> exactly one planner
  execution, everyone gets the bit-identical answer.

The toy planner here is a deterministic pure function (a greedy
downgrade over synthetic per-op tables, plus a deliberate sleep to
widen race windows); what is being validated is the *machinery* around
it, not the search arithmetic — service_mirror.py owns that.

Checks:

1. Channel: FIFO order, capacity blocking, close-then-drain, and that
   close wakes blocked receivers.
2. Histogram: binning and quantiles reproduce the reference vectors in
   rust/src/service/telemetry.rs's unit tests.
3. 8 identical concurrent socket queries run exactly one search, proven
   through the wire via the ``stats`` verb; all 8 answers bit-identical.
4. Concurrent distinct queries match a serial replay bit for bit.
5. Telemetry consistency under concurrent, partly hostile load:
   histogram counts == queries, hits + misses == queries - rejected.
6. Framing: an oversized line gets a structured error and a closed
   socket; an idle connection times out without wedging its worker.
7. ``shutdown`` acks, drains, and the listener stops accepting.

PR 7 adds the fault-injection mirror: ``OSDP_FAULTS`` is parsed with
the same grammar and the same splitmix64 ``(seed, site, call)`` mix as
``rust/src/util/faults.rs``, injected at the same boundaries — a
panicking dispatch (before any accounting, so the telemetry
invariants stay exact), a slow dispatch, and a mid-line socket reset
— and the worker pool self-heals exactly like ``frontend.rs``: the
panic unwinds the served request, bumps ``worker_restarts``, and the
thread re-enters its dispatch loop.

Run: ``python3 python/mirror/frontend_mirror.py`` (exits non-zero on
any mismatch). ``--serve`` starts the mirror server on an ephemeral
port and prints the same ``{"addr":...,"kind":"listening","ok":true}``
line the Rust binary prints, so python/tests/drive_frontend.py can
drive either implementation with the same assertions (chaos mode
included).
"""

import json
import os
import socket
import sys
import threading
import time
from collections import OrderedDict

# --------------------------------------------------- telemetry mirror

LATENCY_BUCKETS_S = [
    1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0,
]
N_BUCKETS = len(LATENCY_BUCKETS_S) + 1
MAX_LINE = 16 * 1024

COUNTERS = [
    "connections", "conn_timeouts", "requests", "bad_requests",
    "queries", "rejected", "infeasible", "warmup_replans",
    "warmup_failures", "worker_restarts",
]


# ------------------------------------------------ fault-plan mirror
#
# util/faults.rs: a deterministic fault schedule parsed once from
# OSDP_FAULTS. Whether call n of a site fires is a pure function of
# (seed, site, n) — the same splitmix64-style mix as the Rust side —
# so a given seed produces the same fault counts in both
# implementations. The cache-io site is parsed but never consulted
# here (the toy service has no disk cache); the other three drive the
# same boundaries the Rust front-end hardens.

MASK64 = (1 << 64) - 1
SITE_SEARCH_PANIC, SITE_SEARCH_SLOW, SITE_CACHE_IO, SITE_SOCK_RESET = \
    range(4)
_FAULT_KEYS = ("seed", "panic", "slow", "slow-ms", "cache-io",
               "sock-reset")


class InjectedFault(Exception):
    """faults.rs::on_query_dispatch's panic, as an exception."""


def fault_mix(seed, site, n):
    """faults.rs::mix — splitmix64 finalizer over (seed, site, call)."""
    z = (seed * 0x9E3779B97F4A7C15 + site * 0xBF58476D1CE4E5B9
         + ((n + 0x94D049BB133111EB) & MASK64)) & MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return z ^ (z >> 31)


class FaultState:
    def __init__(self, spec):
        plan = {k: 0 for k in _FAULT_KEYS}
        for tok in spec.split(","):
            tok = tok.strip()
            if not tok:
                continue
            if ":" not in tok:
                raise ValueError(f"fault token {tok!r} is not key:value")
            key, value = tok.split(":", 1)
            key = key.strip()
            if key not in plan:
                raise ValueError(f"unknown fault key {key!r}")
            if not value.strip().isdigit():
                raise ValueError(
                    f"fault value {value!r} is not an unsigned integer")
            plan[key] = int(value)
        for k in ("panic", "slow", "cache-io", "sock-reset"):
            if plan[k] > 1_000_000:
                raise ValueError(f"fault rate {plan[k]} exceeds 1000000")
        self.seed = plan["seed"]
        self.slow_ms = plan["slow-ms"]
        self.rates = [plan["panic"], plan["slow"], plan["cache-io"],
                      plan["sock-reset"]]
        self.calls = [0] * 4
        self._lock = threading.Lock()

    def fires(self, site):
        rate = self.rates[site]
        if rate == 0:
            return False
        with self._lock:
            n = self.calls[site]
            self.calls[site] += 1
        return fault_mix(self.seed, site, n) % 1_000_000 < rate


_FAULTS = None
_FAULTS_LOCK = threading.Lock()


def faults():
    """Process-wide fault state from OSDP_FAULTS; a malformed spec
    exits 2 (a chaos run that silently injects nothing proves
    nothing), exactly like faults.rs::global."""
    global _FAULTS
    with _FAULTS_LOCK:
        if _FAULTS is None:
            try:
                _FAULTS = FaultState(os.environ.get("OSDP_FAULTS", ""))
            except ValueError as e:
                print(f"mirror: bad OSDP_FAULTS spec: {e}",
                      file=sys.stderr)
                sys.exit(2)
        return _FAULTS


def on_query_dispatch():
    """faults.rs::on_query_dispatch — maybe sleep, maybe raise, before
    any telemetry or cache accounting."""
    st = faults()
    if st.fires(SITE_SEARCH_SLOW):
        time.sleep(max(st.slow_ms, 1) / 1000.0)
    if st.fires(SITE_SEARCH_PANIC):
        raise InjectedFault("injected fault: search panicked")


def bucket_of(seconds):
    """telemetry.rs::Histogram::bucket_of — first bound not exceeded."""
    for i, b in enumerate(LATENCY_BUCKETS_S):
        if seconds <= b:
            return i
    return len(LATENCY_BUCKETS_S)


class Histogram:
    def __init__(self):
        self.buckets = [0] * N_BUCKETS
        self.count = 0
        self.sum_s = 0.0
        self._lock = threading.Lock()

    def observe(self, seconds):
        s = seconds if (seconds == seconds and 0.0 <= seconds
                        != float("inf")) else 0.0
        with self._lock:
            self.buckets[bucket_of(s)] += 1
            self.count += 1
            self.sum_s += s

    def quantile(self, q):
        """telemetry.rs::Histogram::quantile — bucket upper bound of
        rank ceil(q * count); the overflow bucket reports the last
        finite bound."""
        with self._lock:
            total = self.count
            snap = list(self.buckets)
        if total == 0:
            return None
        rank = min(max(int(-(-min(max(q, 0.0), 1.0) * total // 1)), 1),
                   total)
        cum = 0
        for i, c in enumerate(snap):
            cum += c
            if cum >= rank:
                return LATENCY_BUCKETS_S[min(i,
                                             len(LATENCY_BUCKETS_S) - 1)]
        return LATENCY_BUCKETS_S[-1]


class Telemetry:
    def __init__(self):
        self._lock = threading.Lock()
        self.counters = {name: 0 for name in COUNTERS}
        self.batch_latency = Histogram()
        self.sweep_latency = Histogram()

    def bump(self, name):
        with self._lock:
            self.counters[name] += 1

    def get(self, name):
        with self._lock:
            return self.counters[name]

    def observe_query(self, sweep, seconds, error_kind):
        self.bump("queries")
        (self.sweep_latency if sweep else self.batch_latency).observe(
            seconds)
        if error_kind == "infeasible":
            self.bump("infeasible")
        elif error_kind is not None:
            self.bump("rejected")

    def to_json(self):
        with self._lock:
            doc = dict(self.counters)
        doc["latency"] = {
            "batch": {"count": self.batch_latency.count},
            "sweep": {"count": self.sweep_latency.count},
        }
        return doc


# ----------------------------------------------------- channel mirror


class Channel:
    """frontend.rs::Channel — bounded MPMC queue on a mutex + two
    condition variables, with close-then-drain semantics."""

    def __init__(self, cap):
        self.cap = max(cap, 1)
        self.queue = []
        self.closed = False
        self._lock = threading.Lock()
        self.not_empty = threading.Condition(self._lock)
        self.not_full = threading.Condition(self._lock)

    def send(self, item):
        with self._lock:
            while len(self.queue) >= self.cap and not self.closed:
                self.not_full.wait()
            if self.closed:
                return False
            self.queue.append(item)
            self.not_empty.notify()
            return True

    def recv(self):
        with self._lock:
            while not self.queue and not self.closed:
                self.not_empty.wait()
            if self.queue:
                item = self.queue.pop(0)
                self.not_full.notify()
                return item
            return None  # closed and drained

    def close(self):
        with self._lock:
            self.closed = True
            self.not_empty.notify_all()
            self.not_full.notify_all()


# ------------------------------------------- toy service (single-flight)


def toy_tables(setting, n_ops=12, n_opts=4):
    """Deterministic synthetic per-op (time, mem) tables derived from
    the setting string — a pure function, so every process and thread
    agrees on the optimum."""
    h = 2166136261
    for ch in setting.encode():
        h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
    tables = []
    for i in range(n_ops):
        opts = []
        for c in range(n_opts):
            h = (h * 1103515245 + 12345) & 0x7FFFFFFF
            t = 1.0 + (h % 997) / 997.0 + 2.0 * c
            m = 100.0 / (1 + c) + (h % 89)
            opts.append((t, m))
        tables.append(opts)
    return tables


def toy_plan(setting, mem, batch):
    """The toy planner: greedy downgrade until the plan fits, else
    infeasible. Deterministic; sleeps to widen the coalescing window."""
    time.sleep(0.02)
    tables = toy_tables(setting)
    choice = [0] * len(tables)
    peak = lambda ch: batch * sum(t[c][1] for t, c in zip(tables, ch))
    while peak(choice) > mem * 1024.0:
        moves = [i for i, c in enumerate(choice)
                 if c + 1 < len(tables[i])]
        if not moves:
            return None
        # largest memory saving first, index as the deterministic tie-break
        i = max(moves, key=lambda i: (
            tables[i][choice[i]][1] - tables[i][choice[i] + 1][1], -i))
        choice[i] += 1
    t = batch * sum(t[c][0] for t, c in zip(tables, choice))
    return {"choice": choice, "time_s": round(t, 9),
            "peak": peak(choice)}


class ToyService:
    """The service core contract: LRU cache + single-flight coalescing.
    Mirrors PlanService's stats transitions (hits, misses, coalesced,
    planner_runs) so the stats-verb assertions carry over."""

    def __init__(self, capacity=256):
        self._lock = threading.Lock()
        self.cache = OrderedDict()
        self.flights = {}
        self.stats = {"hits": 0, "misses": 0, "coalesced": 0,
                      "planner_runs": 0}

    def query(self, setting, mem, batch):
        key = (setting, round(float(mem), 9), int(batch))
        with self._lock:
            if key in self.cache:
                self.cache.move_to_end(key)
                self.stats["hits"] += 1
                return dict(self.cache[key], source="cache")
            self.stats["misses"] += 1
            flight = self.flights.get(key)
            if flight is None:
                flight = {"done": threading.Event(), "value": None}
                self.flights[key] = flight
                leader = True
            else:
                leader = False
                self.stats["coalesced"] += 1
        if not leader:
            flight["done"].wait()
            value = flight["value"]
            return None if value is None else dict(value,
                                                   source="coalesced")
        with self._lock:
            self.stats["planner_runs"] += 1
        value = toy_plan(setting, mem, batch)
        with self._lock:
            if value is not None:
                self.cache[key] = value
                while len(self.cache) > 256:
                    self.cache.popitem(last=False)
            flight["value"] = value
            del self.flights[key]
        flight["done"].set()
        return None if value is None else dict(value, source="cold")


# --------------------------------------------------- front-end mirror


def handle_line(service, telemetry, line):
    """server.rs::handle_line_full for the mirror grammar subset:
    query / stats / quit / shutdown."""
    parts = line.split()
    verb, kv = parts[0], {}
    for p in parts[1:]:
        if "=" not in p:
            telemetry.bump("bad_requests")
            return (json.dumps({"ok": False, "error": "bad-request",
                                "detail": f"malformed token {p!r}"}),
                    "continue")
        k, v = p.split("=", 1)
        kv[k] = v
    if verb == "quit":
        return json.dumps({"kind": "bye", "ok": True}), "quit"
    if verb == "shutdown":
        return json.dumps({"kind": "shutdown", "ok": True}), "shutdown"
    if verb == "stats":
        with service._lock:
            doc = dict(service.stats)
        doc.update(ok=True, kind="stats", telemetry=telemetry.to_json())
        return json.dumps(doc), "continue"
    if verb != "query":
        telemetry.bump("bad_requests")
        return (json.dumps({"ok": False, "error": "bad-request",
                            "detail": f"unknown verb {verb!r}"}),
                "continue")
    try:
        setting = kv["setting"]
        mem = float(kv["mem"])
        batch = int(kv["batch"])
        if batch < 1 or mem != mem or mem <= 0:
            raise ValueError(batch)
    except (KeyError, ValueError):
        telemetry.bump("bad_requests")
        return (json.dumps({"ok": False, "error": "bad-request",
                            "detail": "query needs setting= mem= batch="}),
                "continue")
    # dispatch boundary: an injected panic fires BEFORE any query
    # accounting, so a killed query counts nowhere and the telemetry
    # invariants stay exact under chaos (mod.rs places the Rust hook
    # at the top of query_seeded for the same reason)
    on_query_dispatch()
    t0 = time.monotonic()
    if setting.startswith("nope"):
        telemetry.observe_query(False, time.monotonic() - t0,
                                "unknown-setting")
        return (json.dumps({"ok": False, "error": "unknown-setting",
                            "detail": setting}), "continue")
    resp = service.query(setting, mem, batch)
    if resp is None:
        telemetry.observe_query(False, time.monotonic() - t0,
                                "infeasible")
        return (json.dumps({"ok": False, "error": "infeasible",
                            "detail": f"nothing fits at b={batch}"}),
                "continue")
    telemetry.observe_query(False, time.monotonic() - t0, None)
    resp = dict(resp, ok=True, kind="plan", batch=batch)
    return json.dumps(resp, sort_keys=True), "continue"


class Frontend:
    """frontend.rs::Frontend — acceptor + bounded worker pool."""

    POLL_TICK = 0.05

    def __init__(self, service, telemetry, workers=4, idle_timeout=30.0,
                 queue_cap=64):
        self.service = service
        self.telemetry = telemetry
        self.idle_timeout = idle_timeout
        self.shutdown_flag = threading.Event()
        self.listener = socket.create_server(("127.0.0.1", 0))
        self.addr = self.listener.getsockname()
        self.conns = Channel(queue_cap)
        self.acceptor = threading.Thread(target=self._accept,
                                         daemon=True)
        self.acceptor.start()
        self.workers = [
            threading.Thread(target=self._work, daemon=True)
            for _ in range(max(workers, 1))
        ]
        for w in self.workers:
            w.start()

    def _accept(self):
        try:
            while True:
                conn, _ = self.listener.accept()
                if self.shutdown_flag.is_set():
                    conn.close()
                    break
                self.telemetry.bump("connections")
                if not self.conns.send(conn):
                    conn.close()
                    break
        except OSError:
            pass
        finally:
            self.listener.close()
            self.conns.close()  # workers drain the queue, then exit

    def _work(self):
        # frontend.rs worker loop: a panic anywhere in a served
        # request unwinds out (the peer sees its connection drop,
        # nothing more), is counted as a worker restart, and the same
        # thread re-enters the dispatch loop — the pool can never
        # shrink from panics
        while True:
            try:
                while True:
                    conn = self.conns.recv()
                    if conn is None:
                        return
                    try:
                        self._serve(conn)
                    finally:
                        conn.close()
            except Exception:
                self.telemetry.bump("worker_restarts")

    def _read_line(self, conn, buf):
        """read_request_line: assemble one line, cap at MAX_LINE,
        charge wait time against the idle budget, poll the shutdown
        flag."""
        started = time.monotonic()
        while True:
            if self.shutdown_flag.is_set():
                return "shutdown", None, buf
            nl = buf.find(b"\n")
            if nl >= 0:
                line, buf = buf[:nl], buf[nl + 1:]
                if len(line) > MAX_LINE:
                    return "toolong", None, buf
                return "line", line.decode("utf-8", "replace"), buf
            if len(buf) > MAX_LINE:
                return "toolong", None, b""
            try:
                chunk = conn.recv(4096)
            except socket.timeout:
                if time.monotonic() - started >= self.idle_timeout:
                    return "idle", None, buf
                continue
            except OSError:
                return "error", None, buf
            if not chunk:
                return "eof", None, buf
            buf += chunk

    def _serve(self, conn):
        conn.settimeout(self.POLL_TICK)
        buf = b""
        while True:
            kind, line, buf = self._read_line(conn, buf)
            if kind in ("eof", "error", "shutdown"):
                return
            if kind == "idle":
                self.telemetry.bump("conn_timeouts")
                self._send(conn, json.dumps(
                    {"ok": False, "error": "timeout",
                     "detail": "idle connection closed"}))
                return
            if kind == "toolong":
                self.telemetry.bump("requests")
                self.telemetry.bump("bad_requests")
                self._send(conn, json.dumps(
                    {"ok": False, "error": "bad-request",
                     "detail": f"request line exceeds {MAX_LINE} bytes"}))
                # drain so close() is a FIN, not an RST (frontend.rs
                # does the same before hanging up)
                drained = 0
                while drained < (1 << 20):
                    try:
                        chunk = conn.recv(4096)
                    except OSError:
                        break
                    if not chunk:
                        break
                    drained += len(chunk)
                return
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            self.telemetry.bump("requests")
            resp, outcome = handle_line(self.service, self.telemetry,
                                        line)
            if faults().fires(SITE_SOCK_RESET):
                # frontend.rs sock-reset: tear the response mid-line
                # and slam the connection — after handle_line, so all
                # accounting already happened; a torn `shutdown` ack
                # must still shut down or chaos makes us immortal
                raw = resp.encode()
                try:
                    conn.sendall(raw[:len(raw) // 2])
                except OSError:
                    pass
                if outcome == "shutdown":
                    self.shutdown()
                return
            if not self._send(conn, resp):
                return
            if outcome == "quit":
                return
            if outcome == "shutdown":
                self.shutdown()
                return

    @staticmethod
    def _send(conn, line):
        try:
            conn.sendall(line.encode() + b"\n")
            return True
        except OSError:
            return False

    def shutdown(self):
        if self.shutdown_flag.is_set():
            return
        self.shutdown_flag.set()
        try:  # wake the blocked accept() exactly like Frontend::shutdown
            socket.create_connection(self.addr, timeout=1).close()
        except OSError:
            pass

    def join(self):
        self.acceptor.join()
        for w in self.workers:
            w.join()


# ---------------------------------------------------------------- checks


def check(cond, msg, ctx=""):
    if not cond:
        print("FAIL:", msg)
        if ctx:
            print("  ctx:", ctx)
        sys.exit(1)


def client(addr, lines, timeout=30.0):
    """One connection, one response line per request line."""
    out = []
    with socket.create_connection(addr, timeout=timeout) as s:
        f = s.makefile("rwb")
        for line in lines:
            f.write(line.encode() + b"\n")
            f.flush()
            resp = f.readline()
            check(resp.endswith(b"\n"), "response not newline-framed",
                  resp)
            out.append(json.loads(resp))
    return out


def check_channel():
    ch = Channel(2)
    check(ch.send(1) and ch.send(2), "sends under capacity succeed")
    got = []
    t = threading.Thread(target=lambda: got.append(ch.send(3)))
    t.start()
    time.sleep(0.05)
    check(t.is_alive(), "send must block at capacity")
    check(ch.recv() == 1, "FIFO order")
    t.join(timeout=5)
    check(got == [True], "blocked send completes after recv")
    check(ch.recv() == 2 and ch.recv() == 3, "FIFO order after unblock")
    ch.send(4)
    ch.close()
    check(ch.recv() == 4, "close drains queued items first")
    check(ch.recv() is None, "then reports the end")
    ch2 = Channel(1)
    res = []
    t2 = threading.Thread(target=lambda: res.append(ch2.recv()))
    t2.start()
    time.sleep(0.05)
    ch2.close()
    t2.join(timeout=5)
    check(res == [None], "close wakes blocked receivers")
    print("channel mirror OK")


def check_histogram():
    # the reference vectors from telemetry.rs::buckets_bin_and_quantile
    check(bucket_of(0.0) == 0 and bucket_of(1e-5) == 0, "bucket 0 edge")
    check(bucket_of(1.1e-5) == 1 and bucket_of(0.5) == 10, "binning")
    check(bucket_of(2.0) == 11, "overflow bucket")
    h = Histogram()
    check(h.quantile(0.5) is None, "empty histogram")
    for _ in range(98):
        h.observe(2e-5)
    h.observe(0.02)
    h.observe(5.0)
    check(h.count == 100, "count")
    check(h.buckets[1] == 98 and h.buckets[7] == 1
          and h.buckets[-1] == 1, "bucket placement", h.buckets)
    check(h.quantile(0.5) == 3e-5, "p50", h.quantile(0.5))
    check(h.quantile(0.99) == 3e-2, "p99", h.quantile(0.99))
    check(h.quantile(1.0) == 1.0, "overflow quotes last finite bound")
    print("histogram mirror OK")


def check_coalescing(frontend):
    addr = frontend.addr
    line = "query setting=deep24 mem=2.0 batch=2"
    barrier = threading.Barrier(8)
    results = [None] * 8

    def one(i):
        barrier.wait()
        results[i] = client(addr, [line])[0]

    threads = [threading.Thread(target=one, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    for r in results:
        check(r is not None and r["ok"], "coalesced query failed", r)
        check(r["choice"] == results[0]["choice"]
              and r["time_s"] == results[0]["time_s"],
              "coalesced answers must be bit-identical", r)
    stats = client(addr, ["stats"])[0]
    check(stats["planner_runs"] == 1,
          "8 identical concurrent queries must run exactly one search",
          stats)
    check(stats["hits"] + stats["coalesced"] == 7,
          "everyone but the leader shares", stats)
    check(stats["telemetry"]["queries"] == 8, "telemetry rides along",
          stats)
    print("socket coalescing OK: 8 queries -> 1 planner run")


def check_distinct_vs_serial(frontend):
    addr = frontend.addr
    lines = [f"query setting=model{i} mem={1.0 + 0.5 * i} batch={1 + i % 3}"
             for i in range(6)]
    serial = [toy_plan(f"model{i}", 1.0 + 0.5 * i, 1 + i % 3)
              for i in range(6)]
    barrier = threading.Barrier(6)
    results = [None] * 6

    def one(i):
        barrier.wait()
        results[i] = client(addr, [lines[i]])[0]

    threads = [threading.Thread(target=one, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    for got, want in zip(results, serial):
        check(got["ok"] and got["choice"] == want["choice"]
              and got["time_s"] == want["time_s"],
              "concurrent distinct != serial", (got, want))
    print("distinct-vs-serial bit-identity OK")


def check_telemetry_consistency():
    service, telemetry = ToyService(), Telemetry()
    frontend = Frontend(service, telemetry, workers=4)
    addr = frontend.addr
    script = ["query setting=tele mem=3.0 batch=1",
              "frobnicate the planner",
              "query setting=nope mem=4 batch=1"]
    barrier = threading.Barrier(6)

    def one():
        barrier.wait()
        r = client(addr, script)
        check(r[0]["ok"], "good query failed", r[0])
        check(r[1]["error"] == "bad-request", "junk not rejected", r[1])
        check(r[2]["error"] == "unknown-setting", "bad setting", r[2])

    threads = [threading.Thread(target=one) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    frontend.shutdown()
    frontend.join()
    check(telemetry.get("requests") == 18, "3 lines x 6 conns",
          telemetry.to_json())
    check(telemetry.get("queries") == 12, "parsed queries",
          telemetry.to_json())
    check(telemetry.get("bad_requests") == 6, "junk lines",
          telemetry.to_json())
    check(telemetry.get("rejected") == 6, "unknown settings",
          telemetry.to_json())
    check(telemetry.batch_latency.count == telemetry.get("queries"),
          "histogram count == queries", telemetry.to_json())
    check(service.stats["hits"] + service.stats["misses"]
          == telemetry.get("queries") - telemetry.get("rejected"),
          "hits + misses == validated queries",
          (service.stats, telemetry.to_json()))
    check(service.stats["planner_runs"] == 1,
          "6 identical good queries -> one run", service.stats)
    print("telemetry consistency OK")


def check_framing():
    service, telemetry = ToyService(), Telemetry()
    frontend = Frontend(service, telemetry, workers=1, idle_timeout=0.2)
    addr = frontend.addr
    # oversized line: structured error, then hangup
    with socket.create_connection(addr, timeout=30) as s:
        s.sendall(b"x" * (64 * 1024))
        f = s.makefile("rb")
        doc = json.loads(f.readline())
        check(doc["error"] == "bad-request", "oversized line", doc)
        check(f.read() == b"", "socket closes after oversized line")
    # idle connection: timeout error, worker survives
    with socket.create_connection(addr, timeout=30) as s:
        f = s.makefile("rb")
        doc = json.loads(f.readline())
        check(doc["error"] == "timeout", "idle timeout", doc)
        check(f.read() == b"", "socket closes after idle timeout")
    check(telemetry.get("conn_timeouts") == 1, "timeout counted")
    stats = client(addr, ["stats"])[0]
    check(stats["kind"] == "stats", "the 1-worker pool is not wedged")
    frontend.shutdown()
    frontend.join()
    print("framing (oversized + idle timeout) OK")


def check_shutdown():
    service, telemetry = ToyService(), Telemetry()
    frontend = Frontend(service, telemetry, workers=2)
    addr = frontend.addr
    r = client(addr, ["query setting=bye mem=2.0 batch=1", "shutdown"])
    check(r[0]["ok"], "in-flight work completes before the ack", r[0])
    check(r[1] == {"kind": "shutdown", "ok": True}, "shutdown ack", r[1])
    frontend.join()
    try:
        with socket.create_connection(addr, timeout=2) as s:
            s.settimeout(2)
            check(s.makefile("rb").readline() == b"",
                  "no worker serves after shutdown")
    except OSError:
        pass  # refused outright: equally fine
    print("graceful shutdown OK")


def main():
    if "--serve" in sys.argv[1:]:
        frontend = Frontend(ToyService(), Telemetry(), workers=8)
        print(json.dumps({"addr": "%s:%d" % frontend.addr,
                          "kind": "listening", "ok": True}),
              flush=True)
        frontend.join()
        return
    check_channel()
    check_histogram()
    service, telemetry = ToyService(), Telemetry()
    frontend = Frontend(service, telemetry, workers=8)
    check_coalescing(frontend)
    check_distinct_vs_serial(frontend)
    frontend.shutdown()
    frontend.join()
    check_telemetry_consistency()
    check_framing()
    check_shutdown()
    print("OK: all frontend-mirror checks passed")


if __name__ == "__main__":
    main()
