"""Python mirror of the socket front-end's concurrency machinery (PR 6,
in the tradition of service_mirror.py — this container has no Rust
toolchain, so the load-bearing concurrent-systems design is re-validated
here with real threads and real sockets).

Mirrors:

* ``service/frontend.rs::Channel`` — the bounded MPMC handoff between
  the acceptor and the worker pool: FIFO, blocking ``send`` at capacity,
  ``close()`` lets receivers drain queued items then observe the end;
* ``service/frontend.rs::read_request_line`` — newline framing with the
  16 KiB line cap, idle-timeout accounting, and the
  structured-error-then-hangup paths;
* ``service/telemetry.rs`` — the fixed bucket bounds, the binning rule
  (first bound the latency does not exceed), and the bucket-resolution
  quantile estimate;
* the service's single-flight coalescing contract, driven through TCP
  this time: N identical concurrent queries -> exactly one planner
  execution, everyone gets the bit-identical answer.

The toy planner here is a deterministic pure function (a greedy
downgrade over synthetic per-op tables, plus a deliberate sleep to
widen race windows); what is being validated is the *machinery* around
it, not the search arithmetic — service_mirror.py owns that.

Checks:

1. Channel: FIFO order, capacity blocking, close-then-drain, and that
   close wakes blocked receivers.
2. Histogram: binning and quantiles reproduce the reference vectors in
   rust/src/service/telemetry.rs's unit tests.
3. 8 identical concurrent socket queries run exactly one search, proven
   through the wire via the ``stats`` verb; all 8 answers bit-identical.
4. Concurrent distinct queries match a serial replay bit for bit.
5. Telemetry consistency under concurrent, partly hostile load:
   histogram counts == queries, hits + misses == queries - rejected.
6. Framing: an oversized line gets a structured error and a closed
   socket; an idle connection times out without wedging its worker.
7. ``shutdown`` acks, drains, and the listener stops accepting.

PR 7 adds the fault-injection mirror: ``OSDP_FAULTS`` is parsed with
the same grammar and the same splitmix64 ``(seed, site, call)`` mix as
``rust/src/util/faults.rs``, injected at the same boundaries — a
panicking dispatch (before any accounting, so the telemetry
invariants stay exact), a slow dispatch, and a mid-line socket reset
— and the worker pool self-heals exactly like ``frontend.rs``: the
panic unwinds the served request, bumps ``worker_restarts``, and the
thread re-enters its dispatch loop.

PR 8 adds the second cache tier: ``--cache-serve`` runs a standalone
cache server (``service/remote.rs::CacheServerHandler``) on the same
front-end machinery, speaking ``get``/``put``/``stats``/``quit``;
``--serve --remote HOST:PORT`` attaches a ``RemoteTier`` mirror —
read-through on an L1 miss, write-behind puts on a bounded queue, a
hard per-operation deadline budget, and a closed/open/half-open
circuit breaker — with three more fault sites (``remote-slow``,
``remote-io``, ``remote-garbage``) at the same indices as faults.rs.
Every remote failure demotes to a local miss; garbage and
version-skewed payloads quarantine instead of changing an answer; the
stats invariant becomes
``hits + remote_hits + misses == queries - rejected``.

PR 10's observability surface (the ``metrics`` / ``trace`` verbs, the
request tracer, the ``--metrics-listen`` scrape endpoint) is
binary-only: the mirror answers those verbs ``bad-request``, which the
driver's chaos mode treats as the mirror signature and skips the
cross-checks. The ``replan`` latency lane *is* mirrored (always 0 —
the mirror grammar has no replan verb) so the three-lane sum invariant
``batch + sweep + replan == queries`` has the same shape on both
implementations.

Run: ``python3 python/mirror/frontend_mirror.py`` (exits non-zero on
any mismatch). ``--serve`` starts the mirror server on an ephemeral
port and prints the same ``{"addr":...,"kind":"listening","ok":true}``
line the Rust binary prints, so python/tests/drive_frontend.py can
drive either implementation with the same assertions (chaos mode
included).
"""

import json
import os
import socket
import sys
import threading
import time
from collections import OrderedDict

# --------------------------------------------------- telemetry mirror

LATENCY_BUCKETS_S = [
    1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0,
]
N_BUCKETS = len(LATENCY_BUCKETS_S) + 1
MAX_LINE = 16 * 1024

COUNTERS = [
    "connections", "conn_timeouts", "requests", "bad_requests",
    "queries", "rejected", "infeasible", "warmup_replans",
    "warmup_failures", "worker_restarts",
]


# ------------------------------------------------ fault-plan mirror
#
# util/faults.rs: a deterministic fault schedule parsed once from
# OSDP_FAULTS. Whether call n of a site fires is a pure function of
# (seed, site, n) — the same splitmix64-style mix as the Rust side —
# so a given seed produces the same fault counts in both
# implementations. The cache-io site is parsed but never consulted
# here (the toy service has no disk cache); the other three drive the
# same boundaries the Rust front-end hardens.

MASK64 = (1 << 64) - 1
(SITE_SEARCH_PANIC, SITE_SEARCH_SLOW, SITE_CACHE_IO, SITE_SOCK_RESET,
 SITE_REMOTE_SLOW, SITE_REMOTE_IO, SITE_REMOTE_GARBAGE) = range(7)
N_SITES = 7
_FAULT_KEYS = ("seed", "panic", "slow", "slow-ms", "cache-io",
               "sock-reset", "remote-slow", "remote-io",
               "remote-garbage")
_RATE_KEYS = ("panic", "slow", "cache-io", "sock-reset", "remote-slow",
              "remote-io", "remote-garbage")


class InjectedFault(Exception):
    """faults.rs::on_query_dispatch's panic, as an exception."""


def fault_mix(seed, site, n):
    """faults.rs::mix — splitmix64 finalizer over (seed, site, call)."""
    z = (seed * 0x9E3779B97F4A7C15 + site * 0xBF58476D1CE4E5B9
         + ((n + 0x94D049BB133111EB) & MASK64)) & MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return z ^ (z >> 31)


class FaultState:
    def __init__(self, spec):
        plan = {k: 0 for k in _FAULT_KEYS}
        for tok in spec.split(","):
            tok = tok.strip()
            if not tok:
                continue
            if ":" not in tok:
                raise ValueError(f"fault token {tok!r} is not key:value")
            key, value = tok.split(":", 1)
            key = key.strip()
            if key not in plan:
                raise ValueError(f"unknown fault key {key!r}")
            if not value.strip().isdigit():
                raise ValueError(
                    f"fault value {value!r} is not an unsigned integer")
            plan[key] = int(value)
        for k in _RATE_KEYS:
            if plan[k] > 1_000_000:
                raise ValueError(f"fault rate {plan[k]} exceeds 1000000")
        self.seed = plan["seed"]
        self.slow_ms = plan["slow-ms"]
        self.rates = [plan[k] for k in _RATE_KEYS]
        self.calls = [0] * N_SITES
        self._lock = threading.Lock()

    def fires(self, site):
        rate = self.rates[site]
        if rate == 0:
            return False
        with self._lock:
            n = self.calls[site]
            self.calls[site] += 1
        return fault_mix(self.seed, site, n) % 1_000_000 < rate


_FAULTS = None
_FAULTS_LOCK = threading.Lock()


def faults():
    """Process-wide fault state from OSDP_FAULTS; a malformed spec
    exits 2 (a chaos run that silently injects nothing proves
    nothing), exactly like faults.rs::global."""
    global _FAULTS
    with _FAULTS_LOCK:
        if _FAULTS is None:
            try:
                _FAULTS = FaultState(os.environ.get("OSDP_FAULTS", ""))
            except ValueError as e:
                print(f"mirror: bad OSDP_FAULTS spec: {e}",
                      file=sys.stderr)
                sys.exit(2)
        return _FAULTS


def on_query_dispatch():
    """faults.rs::on_query_dispatch — maybe sleep, maybe raise, before
    any telemetry or cache accounting."""
    st = faults()
    if st.fires(SITE_SEARCH_SLOW):
        time.sleep(max(st.slow_ms, 1) / 1000.0)
    if st.fires(SITE_SEARCH_PANIC):
        raise InjectedFault("injected fault: search panicked")


def bucket_of(seconds):
    """telemetry.rs::Histogram::bucket_of — first bound not exceeded."""
    for i, b in enumerate(LATENCY_BUCKETS_S):
        if seconds <= b:
            return i
    return len(LATENCY_BUCKETS_S)


class Histogram:
    def __init__(self):
        self.buckets = [0] * N_BUCKETS
        self.count = 0
        self.sum_s = 0.0
        self._lock = threading.Lock()

    def observe(self, seconds):
        s = seconds if (seconds == seconds and 0.0 <= seconds
                        != float("inf")) else 0.0
        with self._lock:
            self.buckets[bucket_of(s)] += 1
            self.count += 1
            self.sum_s += s

    def quantile(self, q):
        """telemetry.rs::Histogram::quantile — bucket upper bound of
        rank ceil(q * count); the overflow bucket reports the last
        finite bound."""
        with self._lock:
            total = self.count
            snap = list(self.buckets)
        if total == 0:
            return None
        rank = min(max(int(-(-min(max(q, 0.0), 1.0) * total // 1)), 1),
                   total)
        cum = 0
        for i, c in enumerate(snap):
            cum += c
            if cum >= rank:
                return LATENCY_BUCKETS_S[min(i,
                                             len(LATENCY_BUCKETS_S) - 1)]
        return LATENCY_BUCKETS_S[-1]


class Telemetry:
    def __init__(self):
        self._lock = threading.Lock()
        self.counters = {name: 0 for name in COUNTERS}
        self.batch_latency = Histogram()
        self.sweep_latency = Histogram()
        # PR 10: the replan lane exists so the mirrored lane-sum
        # invariant (batch + sweep + replan == queries) has the same
        # shape as telemetry.rs; the mirror grammar has no replan verb,
        # so the lane only ever reads 0 here
        self.replan_latency = Histogram()

    def bump(self, name):
        with self._lock:
            self.counters[name] += 1

    def get(self, name):
        with self._lock:
            return self.counters[name]

    def observe_query(self, sweep, seconds, error_kind):
        self.bump("queries")
        (self.sweep_latency if sweep else self.batch_latency).observe(
            seconds)
        if error_kind == "infeasible":
            self.bump("infeasible")
        elif error_kind is not None:
            self.bump("rejected")

    def to_json(self):
        with self._lock:
            doc = dict(self.counters)
        doc["latency"] = {
            "batch": {"count": self.batch_latency.count},
            "sweep": {"count": self.sweep_latency.count},
            "replan": {"count": self.replan_latency.count},
        }
        return doc


# ----------------------------------------------------- channel mirror


class Channel:
    """frontend.rs::Channel — bounded MPMC queue on a mutex + two
    condition variables, with close-then-drain semantics."""

    def __init__(self, cap):
        self.cap = max(cap, 1)
        self.queue = []
        self.closed = False
        self._lock = threading.Lock()
        self.not_empty = threading.Condition(self._lock)
        self.not_full = threading.Condition(self._lock)

    def send(self, item):
        with self._lock:
            while len(self.queue) >= self.cap and not self.closed:
                self.not_full.wait()
            if self.closed:
                return False
            self.queue.append(item)
            self.not_empty.notify()
            return True

    def try_send(self, item):
        """frontend.rs::Channel::try_send — non-blocking; False when
        full or closed (the write-behind tier sheds instead of
        stalling a query)."""
        with self._lock:
            if self.closed or len(self.queue) >= self.cap:
                return False
            self.queue.append(item)
            self.not_empty.notify()
            return True

    def recv(self):
        with self._lock:
            while not self.queue and not self.closed:
                self.not_empty.wait()
            if self.queue:
                item = self.queue.pop(0)
                self.not_full.notify()
                return item
            return None  # closed and drained

    def close(self):
        with self._lock:
            self.closed = True
            self.not_empty.notify_all()
            self.not_full.notify_all()


# ------------------------------------------- toy service (single-flight)


def toy_tables(setting, n_ops=12, n_opts=4):
    """Deterministic synthetic per-op (time, mem) tables derived from
    the setting string — a pure function, so every process and thread
    agrees on the optimum."""
    h = 2166136261
    for ch in setting.encode():
        h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
    tables = []
    for i in range(n_ops):
        opts = []
        for c in range(n_opts):
            h = (h * 1103515245 + 12345) & 0x7FFFFFFF
            t = 1.0 + (h % 997) / 997.0 + 2.0 * c
            m = 100.0 / (1 + c) + (h % 89)
            opts.append((t, m))
        tables.append(opts)
    return tables


def toy_plan(setting, mem, batch):
    """The toy planner: greedy downgrade until the plan fits, else
    infeasible. Deterministic; sleeps to widen the coalescing window."""
    time.sleep(0.02)
    tables = toy_tables(setting)
    choice = [0] * len(tables)
    peak = lambda ch: batch * sum(t[c][1] for t, c in zip(tables, ch))
    while peak(choice) > mem * 1024.0:
        moves = [i for i, c in enumerate(choice)
                 if c + 1 < len(tables[i])]
        if not moves:
            return None
        # largest memory saving first, index as the deterministic tie-break
        i = max(moves, key=lambda i: (
            tables[i][choice[i]][1] - tables[i][choice[i] + 1][1], -i))
        choice[i] += 1
    t = batch * sum(t[c][0] for t, c in zip(tables, choice))
    return {"choice": choice, "time_s": round(t, 9),
            "peak": peak(choice)}


# ------------------------------------------------ cache-tier mirror
#
# service/remote.rs: a standalone cache server (the same front-end
# machinery with a different line handler) and a RemoteTier client —
# read-through on an L1 miss, write-behind puts on a bounded queue, a
# hard per-operation deadline budget, and a closed/open/half-open
# circuit breaker. Entries carry a schema version; anything that does
# not parse, validate, or match its key quarantines instead of ever
# becoming an answer.

ENTRY_SCHEMA = 1


def canonical_req(setting, mem_r, batch):
    """The canonical request line both instances derive from parsed
    values — the cross-instance cache key (server.rs::request_line)."""
    return f"query setting={setting} mem={mem_r!r} batch={batch}"


def entry_of(req, value):
    return {"schema": ENTRY_SCHEMA, "req": req,
            "choice": value["choice"], "time_s": value["time_s"],
            "peak": value["peak"]}


def validate_entry(entry, setting, mem_r, batch, req):
    """remote.rs::entry_from_json + CachedValue::validates_against:
    schema, key equality, shape, and a full re-derivation of the costs
    from the pure tables — a lying cache can never change a plan."""
    if not isinstance(entry, dict) or entry.get("schema") != ENTRY_SCHEMA:
        return None
    if entry.get("req") != req:
        return None
    choice = entry.get("choice")
    tables = toy_tables(setting)
    if (not isinstance(choice, list) or len(choice) != len(tables)
            or not all(isinstance(c, int) and 0 <= c < len(t)
                       for c, t in zip(choice, tables))):
        return None
    peak = batch * sum(t[c][1] for t, c in zip(tables, choice))
    t = batch * sum(t[c][0] for t, c in zip(tables, choice))
    if peak > mem_r * 1024.0:
        return None
    value = {"choice": choice, "time_s": round(t, 9), "peak": peak}
    if (value["time_s"] != entry.get("time_s")
            or value["peak"] != entry.get("peak")):
        return None
    return value


def bad_request(detail):
    return json.dumps({"ok": False, "error": "bad-request",
                       "detail": detail})


class CacheHandler:
    """remote.rs::CacheServerHandler — the second-tier store behind
    the shared front-end: ``get <req>`` / ``put <entry-json>`` /
    ``stats`` / ``quit`` / ``shutdown``. Puts are validated wholesale;
    a bad put is refused, never stored."""

    def __init__(self, capacity=4096):
        self.capacity = max(capacity, 1)
        self._lock = threading.Lock()
        self.store = OrderedDict()
        self.counters = {"gets": 0, "hits": 0, "puts": 0, "bad_puts": 0}

    def handle(self, line):
        verb, _, rest = line.partition(" ")
        rest = rest.strip()
        if verb == "quit":
            return json.dumps({"kind": "bye", "ok": True}), "quit"
        if verb == "shutdown":
            return (json.dumps({"kind": "shutdown", "ok": True}),
                    "shutdown")
        if verb == "stats":
            with self._lock:
                doc = dict(self.counters, entries=len(self.store))
            doc.update(ok=True, kind="stats")
            return json.dumps(doc, sort_keys=True), "continue"
        if verb == "get":
            if not rest:
                return bad_request("get needs a request-line key"), \
                    "continue"
            with self._lock:
                self.counters["gets"] += 1
                entry = self.store.get(rest)
                if entry is not None:
                    self.store.move_to_end(rest)
                    self.counters["hits"] += 1
            doc = {"ok": True, "kind": "get", "hit": entry is not None}
            if entry is not None:
                doc["entry"] = entry
            return json.dumps(doc, sort_keys=True), "continue"
        if verb == "put":
            try:
                entry = json.loads(rest)
            except ValueError:
                entry = None
            ok = (isinstance(entry, dict)
                  and entry.get("schema") == ENTRY_SCHEMA
                  and isinstance(entry.get("req"), str) and entry["req"]
                  and isinstance(entry.get("choice"), list)
                  and all(isinstance(c, int) for c in entry["choice"]))
            with self._lock:
                if ok:
                    self.counters["puts"] += 1
                    self.store[entry["req"]] = entry
                    self.store.move_to_end(entry["req"])
                    while len(self.store) > self.capacity:
                        self.store.popitem(last=False)
                else:
                    self.counters["bad_puts"] += 1
            if not ok:
                return bad_request("unparseable or version-skewed " \
                                   "entry"), "continue"
            return (json.dumps({"ok": True, "kind": "put",
                                "stored": True}, sort_keys=True),
                    "continue")
        return bad_request(f"unknown verb {verb!r}"), "continue"


class RemoteTier:
    """remote.rs::RemoteTier — the L2 client. Reads are single-shot
    under a hard deadline budget; puts are write-behind on a bounded
    queue with a dedicated writer; consecutive failures trip a
    closed -> open -> half-open circuit breaker. Every failure mode
    demotes to 'the tier does not exist': skipped, never fatal."""

    def __init__(self, addr, deadline_s=0.005, threshold=3,
                 cooldown_s=0.25, queue_cap=64):
        host, _, port = addr.rpartition(":")
        self.addr = (host or "127.0.0.1", int(port))
        self.deadline_s = max(deadline_s, 0.001)
        self.threshold = max(threshold, 1)
        self.cooldown_s = cooldown_s
        self._lock = threading.Lock()
        self.state = ("closed", 0)
        self.counters = {"remote_errors": 0, "remote_timeouts": 0,
                         "breaker_open": 0}
        self.pending = 0
        self.queue = Channel(queue_cap)
        self.writer = threading.Thread(target=self._write_behind,
                                       daemon=True)
        self.writer.start()

    # breaker -----------------------------------------------------

    def admit(self):
        with self._lock:
            kind = self.state[0]
            if kind == "closed":
                return True
            if kind == "half-open":
                return False  # one probe at a time
            if time.monotonic() - self.state[1] >= self.cooldown_s:
                self.state = ("half-open",)
                return True
            return False

    def _on_ok(self):
        with self._lock:
            self.state = ("closed", 0)

    def _on_fail(self):
        with self._lock:
            kind = self.state[0]
            if kind == "closed":
                fails = self.state[1] + 1
                if fails >= self.threshold:
                    self.state = ("open", time.monotonic())
                    self.counters["breaker_open"] += 1
                else:
                    self.state = ("closed", fails)
            elif kind == "half-open":
                self.state = ("open", time.monotonic())
                self.counters["breaker_open"] += 1

    def breaker_state(self):
        with self._lock:
            return self.state[0]

    def get_counter(self, name):
        with self._lock:
            return self.counters[name]

    # wire --------------------------------------------------------

    def _roundtrip(self, line):
        """One request line, one response line, all under the deadline
        budget — connect, write, and every read pass re-arm the socket
        timeout with the remaining budget, so a slow-loris server
        costs at most the deadline. Fault hooks fire before any I/O,
        exactly like remote.rs."""
        st = faults()
        if st.fires(SITE_REMOTE_IO):
            return "error", None
        deadline = time.monotonic() + self.deadline_s
        if st.fires(SITE_REMOTE_SLOW):
            time.sleep(max(deadline - time.monotonic(), 0.0))
            return "timeout", None
        try:
            s = socket.create_connection(
                self.addr, timeout=max(deadline - time.monotonic(),
                                       1e-4))
        except socket.timeout:
            return "timeout", None
        except OSError:
            return "error", None
        with s:
            try:
                s.settimeout(max(deadline - time.monotonic(), 1e-4))
                s.sendall(line.encode() + b"\n")
                buf = b""
                while b"\n" not in buf:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return "timeout", None
                    s.settimeout(remaining)
                    chunk = s.recv(4096)
                    if not chunk:
                        return "error", None
                    buf += chunk
                    if len(buf) > MAX_LINE:
                        return "error", None
            except socket.timeout:
                return "timeout", None
            except OSError:
                return "error", None
        return "ok", buf.split(b"\n", 1)[0].decode("utf-8", "replace")

    def _fail(self, kind):
        with self._lock:
            self.counters["remote_timeouts" if kind == "timeout"
                          else "remote_errors"] += 1
        self._on_fail()

    def get(self, req):
        """Read-through: ('hit', entry) / 'miss' / 'timeout' / 'error'
        / 'garbage' / 'skipped'. No retries — the deadline IS the
        budget a query is willing to burn on the tier."""
        if not self.admit():
            return "skipped", None
        kind, resp = self._roundtrip("get " + req)
        if kind != "ok":
            self._fail(kind)
            return kind, None
        self._on_ok()  # the transport worked; payload is separate
        if faults().fires(SITE_REMOTE_GARBAGE):
            resp = "\x01garbage " + resp[:len(resp) // 2]
        try:
            doc = json.loads(resp)
        except ValueError:
            return "garbage", None
        if not isinstance(doc, dict) or doc.get("ok") is not True:
            return "garbage", None
        if not doc.get("hit"):
            return "miss", None
        entry = doc.get("entry")
        if not isinstance(entry, dict) or entry.get("req") != req:
            return "garbage", None
        return "hit", entry

    def put(self, entry):
        """Write-behind: enqueue and return; a full queue sheds."""
        line = "put " + json.dumps(entry, sort_keys=True)
        with self._lock:
            self.pending += 1
        if not self.queue.try_send(line):
            with self._lock:
                self.pending -= 1

    def _write_behind(self):
        while True:
            line = self.queue.recv()
            if line is None:
                return
            if self.admit():
                kind = "error"
                for _ in range(3):  # util/backoff.rs: bounded retries
                    kind, _resp = self._roundtrip(line)
                    if kind == "ok":
                        break
                    time.sleep(0.002)
                if kind == "ok":
                    self._on_ok()
                else:
                    self._fail(kind)
            with self._lock:
                self.pending -= 1

    def flush(self, timeout=5.0):
        started = time.monotonic()
        while time.monotonic() - started < timeout:
            with self._lock:
                if self.pending == 0:
                    return True
            time.sleep(0.001)
        return False


class ToyService:
    """The service core contract: LRU cache + single-flight coalescing,
    plus (PR 8) an optional remote second tier consulted between the
    L1 miss and the planner. Mirrors PlanService's stats transitions
    (hits, misses, coalesced, planner_runs, remote_*) so the
    stats-verb assertions carry over."""

    def __init__(self, capacity=256, tier=None):
        self._lock = threading.Lock()
        self.cache = OrderedDict()
        self.flights = {}
        self.tier = tier
        self.stats = {"hits": 0, "misses": 0, "coalesced": 0,
                      "planner_runs": 0, "remote_hits": 0,
                      "remote_misses": 0, "remote_quarantined": 0}

    def _finish(self, key, flight, value):
        with self._lock:
            if value is not None:
                self.cache[key] = value
                while len(self.cache) > 256:
                    self.cache.popitem(last=False)
            flight["value"] = value
            del self.flights[key]
        flight["done"].set()

    def query(self, setting, mem, batch):
        key = (setting, round(float(mem), 9), int(batch))
        with self._lock:
            if key in self.cache:
                self.cache.move_to_end(key)
                self.stats["hits"] += 1
                return dict(self.cache[key], source="cache")
            self.stats["misses"] += 1
            flight = self.flights.get(key)
            if flight is None:
                flight = {"done": threading.Event(), "value": None}
                self.flights[key] = flight
                leader = True
            else:
                leader = False
                self.stats["coalesced"] += 1
        if not leader:
            flight["done"].wait()
            value = flight["value"]
            return None if value is None else dict(value,
                                                   source="coalesced")
        if self.tier is not None:
            # L2 read-through on the L1 miss, before the planner. A
            # hit reclassifies the provisional miss so the invariant
            # hits + remote_hits + misses == queries - rejected stays
            # exact; everything else demotes to a plain local miss.
            req = canonical_req(setting, key[1], key[2])
            kind, entry = self.tier.get(req)
            if kind == "hit":
                value = validate_entry(entry, setting, key[1], key[2],
                                       req)
                if value is not None:
                    with self._lock:
                        self.stats["misses"] -= 1
                        self.stats["remote_hits"] += 1
                    self._finish(key, flight, value)
                    return dict(value, source="remote")
                kind = "garbage"  # validated against the tables: lies
            if kind == "garbage":
                with self._lock:
                    self.stats["remote_quarantined"] += 1
            elif kind == "miss":
                with self._lock:
                    self.stats["remote_misses"] += 1
            # timeout / error / skipped: counted in the tier itself
        with self._lock:
            self.stats["planner_runs"] += 1
        value = toy_plan(setting, mem, batch)
        if value is not None and self.tier is not None:
            self.tier.put(entry_of(canonical_req(setting, key[1],
                                                 key[2]), value))
        self._finish(key, flight, value)
        return None if value is None else dict(value, source="cold")


# --------------------------------------------------- front-end mirror


def handle_line(service, telemetry, line):
    """server.rs::handle_line_full for the mirror grammar subset:
    query / stats / quit / shutdown."""
    parts = line.split()
    verb, kv = parts[0], {}
    for p in parts[1:]:
        if "=" not in p:
            telemetry.bump("bad_requests")
            return (json.dumps({"ok": False, "error": "bad-request",
                                "detail": f"malformed token {p!r}"}),
                    "continue")
        k, v = p.split("=", 1)
        kv[k] = v
    if verb == "quit":
        return json.dumps({"kind": "bye", "ok": True}), "quit"
    if verb == "shutdown":
        return json.dumps({"kind": "shutdown", "ok": True}), "shutdown"
    if verb == "stats":
        with service._lock:
            doc = dict(service.stats)
        if service.tier is not None:
            # merge the tier-owned counters, exactly like
            # PlanService::stats()
            for name in ("remote_errors", "remote_timeouts",
                         "breaker_open"):
                doc[name] = service.tier.get_counter(name)
            doc["breaker"] = service.tier.breaker_state()
        else:
            doc.update(remote_errors=0, remote_timeouts=0,
                       breaker_open=0, breaker="none")
        doc.update(ok=True, kind="stats", telemetry=telemetry.to_json())
        return json.dumps(doc), "continue"
    if verb != "query":
        telemetry.bump("bad_requests")
        return (json.dumps({"ok": False, "error": "bad-request",
                            "detail": f"unknown verb {verb!r}"}),
                "continue")
    try:
        setting = kv["setting"]
        mem = float(kv["mem"])
        batch = int(kv["batch"])
        if batch < 1 or mem != mem or mem <= 0:
            raise ValueError(batch)
    except (KeyError, ValueError):
        telemetry.bump("bad_requests")
        return (json.dumps({"ok": False, "error": "bad-request",
                            "detail": "query needs setting= mem= batch="}),
                "continue")
    # dispatch boundary: an injected panic fires BEFORE any query
    # accounting, so a killed query counts nowhere and the telemetry
    # invariants stay exact under chaos (mod.rs places the Rust hook
    # at the top of query_seeded for the same reason)
    on_query_dispatch()
    t0 = time.monotonic()
    if setting.startswith("nope"):
        telemetry.observe_query(False, time.monotonic() - t0,
                                "unknown-setting")
        return (json.dumps({"ok": False, "error": "unknown-setting",
                            "detail": setting}), "continue")
    resp = service.query(setting, mem, batch)
    if resp is None:
        telemetry.observe_query(False, time.monotonic() - t0,
                                "infeasible")
        return (json.dumps({"ok": False, "error": "infeasible",
                            "detail": f"nothing fits at b={batch}"}),
                "continue")
    telemetry.observe_query(False, time.monotonic() - t0, None)
    resp = dict(resp, ok=True, kind="plan", batch=batch)
    return json.dumps(resp, sort_keys=True), "continue"


class Frontend:
    """frontend.rs::Frontend — acceptor + bounded worker pool. The
    line handler is pluggable (frontend.rs::LineHandler): the plan
    service and the cache server share everything above it."""

    POLL_TICK = 0.05

    def __init__(self, service, telemetry, workers=4, idle_timeout=30.0,
                 queue_cap=64, handler=None):
        self.service = service
        self.telemetry = telemetry
        self.handler = handler or (
            lambda line: handle_line(self.service, self.telemetry,
                                     line))
        self.idle_timeout = idle_timeout
        self.shutdown_flag = threading.Event()
        self.listener = socket.create_server(("127.0.0.1", 0))
        self.addr = self.listener.getsockname()
        self.conns = Channel(queue_cap)
        self.acceptor = threading.Thread(target=self._accept,
                                         daemon=True)
        self.acceptor.start()
        self.workers = [
            threading.Thread(target=self._work, daemon=True)
            for _ in range(max(workers, 1))
        ]
        for w in self.workers:
            w.start()

    def _accept(self):
        try:
            while True:
                conn, _ = self.listener.accept()
                if self.shutdown_flag.is_set():
                    conn.close()
                    break
                self.telemetry.bump("connections")
                if not self.conns.send(conn):
                    conn.close()
                    break
        except OSError:
            pass
        finally:
            self.listener.close()
            self.conns.close()  # workers drain the queue, then exit

    def _work(self):
        # frontend.rs worker loop: a panic anywhere in a served
        # request unwinds out (the peer sees its connection drop,
        # nothing more), is counted as a worker restart, and the same
        # thread re-enters the dispatch loop — the pool can never
        # shrink from panics
        while True:
            try:
                while True:
                    conn = self.conns.recv()
                    if conn is None:
                        return
                    try:
                        self._serve(conn)
                    finally:
                        conn.close()
            except Exception:
                self.telemetry.bump("worker_restarts")

    def _read_line(self, conn, buf):
        """read_request_line: assemble one line, cap at MAX_LINE,
        charge wait time against the idle budget, poll the shutdown
        flag."""
        started = time.monotonic()
        while True:
            if self.shutdown_flag.is_set():
                return "shutdown", None, buf
            nl = buf.find(b"\n")
            if nl >= 0:
                line, buf = buf[:nl], buf[nl + 1:]
                if len(line) > MAX_LINE:
                    return "toolong", None, buf
                return "line", line.decode("utf-8", "replace"), buf
            if len(buf) > MAX_LINE:
                return "toolong", None, b""
            try:
                chunk = conn.recv(4096)
            except socket.timeout:
                if time.monotonic() - started >= self.idle_timeout:
                    return "idle", None, buf
                continue
            except OSError:
                return "error", None, buf
            if not chunk:
                return "eof", None, buf
            buf += chunk

    def _serve(self, conn):
        conn.settimeout(self.POLL_TICK)
        buf = b""
        while True:
            kind, line, buf = self._read_line(conn, buf)
            if kind in ("eof", "error", "shutdown"):
                return
            if kind == "idle":
                self.telemetry.bump("conn_timeouts")
                self._send(conn, json.dumps(
                    {"ok": False, "error": "timeout",
                     "detail": "idle connection closed"}))
                return
            if kind == "toolong":
                self.telemetry.bump("requests")
                self.telemetry.bump("bad_requests")
                self._send(conn, json.dumps(
                    {"ok": False, "error": "bad-request",
                     "detail": f"request line exceeds {MAX_LINE} bytes"}))
                # drain so close() is a FIN, not an RST (frontend.rs
                # does the same before hanging up)
                drained = 0
                while drained < (1 << 20):
                    try:
                        chunk = conn.recv(4096)
                    except OSError:
                        break
                    if not chunk:
                        break
                    drained += len(chunk)
                return
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            self.telemetry.bump("requests")
            resp, outcome = self.handler(line)
            if faults().fires(SITE_SOCK_RESET):
                # frontend.rs sock-reset: tear the response mid-line
                # and slam the connection — after handle_line, so all
                # accounting already happened; a torn `shutdown` ack
                # must still shut down or chaos makes us immortal
                raw = resp.encode()
                try:
                    conn.sendall(raw[:len(raw) // 2])
                except OSError:
                    pass
                if outcome == "shutdown":
                    self.shutdown()
                return
            if not self._send(conn, resp):
                return
            if outcome == "quit":
                return
            if outcome == "shutdown":
                self.shutdown()
                return

    @staticmethod
    def _send(conn, line):
        try:
            conn.sendall(line.encode() + b"\n")
            return True
        except OSError:
            return False

    def shutdown(self):
        if self.shutdown_flag.is_set():
            return
        self.shutdown_flag.set()
        try:  # wake the blocked accept() exactly like Frontend::shutdown
            socket.create_connection(self.addr, timeout=1).close()
        except OSError:
            pass

    def join(self):
        self.acceptor.join()
        for w in self.workers:
            w.join()


# ---------------------------------------------------------------- checks


def check(cond, msg, ctx=""):
    if not cond:
        print("FAIL:", msg)
        if ctx:
            print("  ctx:", ctx)
        sys.exit(1)


def client(addr, lines, timeout=30.0):
    """One connection, one response line per request line."""
    out = []
    with socket.create_connection(addr, timeout=timeout) as s:
        f = s.makefile("rwb")
        for line in lines:
            f.write(line.encode() + b"\n")
            f.flush()
            resp = f.readline()
            check(resp.endswith(b"\n"), "response not newline-framed",
                  resp)
            out.append(json.loads(resp))
    return out


def check_channel():
    ch = Channel(2)
    check(ch.send(1) and ch.send(2), "sends under capacity succeed")
    got = []
    t = threading.Thread(target=lambda: got.append(ch.send(3)))
    t.start()
    time.sleep(0.05)
    check(t.is_alive(), "send must block at capacity")
    check(ch.recv() == 1, "FIFO order")
    t.join(timeout=5)
    check(got == [True], "blocked send completes after recv")
    check(ch.recv() == 2 and ch.recv() == 3, "FIFO order after unblock")
    ch.send(4)
    ch.close()
    check(ch.recv() == 4, "close drains queued items first")
    check(ch.recv() is None, "then reports the end")
    ch2 = Channel(1)
    res = []
    t2 = threading.Thread(target=lambda: res.append(ch2.recv()))
    t2.start()
    time.sleep(0.05)
    ch2.close()
    t2.join(timeout=5)
    check(res == [None], "close wakes blocked receivers")
    print("channel mirror OK")


def check_histogram():
    # the reference vectors from telemetry.rs::buckets_bin_and_quantile
    check(bucket_of(0.0) == 0 and bucket_of(1e-5) == 0, "bucket 0 edge")
    check(bucket_of(1.1e-5) == 1 and bucket_of(0.5) == 10, "binning")
    check(bucket_of(2.0) == 11, "overflow bucket")
    h = Histogram()
    check(h.quantile(0.5) is None, "empty histogram")
    for _ in range(98):
        h.observe(2e-5)
    h.observe(0.02)
    h.observe(5.0)
    check(h.count == 100, "count")
    check(h.buckets[1] == 98 and h.buckets[7] == 1
          and h.buckets[-1] == 1, "bucket placement", h.buckets)
    check(h.quantile(0.5) == 3e-5, "p50", h.quantile(0.5))
    check(h.quantile(0.99) == 3e-2, "p99", h.quantile(0.99))
    check(h.quantile(1.0) == 1.0, "overflow quotes last finite bound")
    print("histogram mirror OK")


def check_coalescing(frontend):
    addr = frontend.addr
    line = "query setting=deep24 mem=2.0 batch=2"
    barrier = threading.Barrier(8)
    results = [None] * 8

    def one(i):
        barrier.wait()
        results[i] = client(addr, [line])[0]

    threads = [threading.Thread(target=one, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    for r in results:
        check(r is not None and r["ok"], "coalesced query failed", r)
        check(r["choice"] == results[0]["choice"]
              and r["time_s"] == results[0]["time_s"],
              "coalesced answers must be bit-identical", r)
    stats = client(addr, ["stats"])[0]
    check(stats["planner_runs"] == 1,
          "8 identical concurrent queries must run exactly one search",
          stats)
    check(stats["hits"] + stats["coalesced"] == 7,
          "everyone but the leader shares", stats)
    check(stats["telemetry"]["queries"] == 8, "telemetry rides along",
          stats)
    print("socket coalescing OK: 8 queries -> 1 planner run")


def check_distinct_vs_serial(frontend):
    addr = frontend.addr
    lines = [f"query setting=model{i} mem={1.0 + 0.5 * i} batch={1 + i % 3}"
             for i in range(6)]
    serial = [toy_plan(f"model{i}", 1.0 + 0.5 * i, 1 + i % 3)
              for i in range(6)]
    barrier = threading.Barrier(6)
    results = [None] * 6

    def one(i):
        barrier.wait()
        results[i] = client(addr, [lines[i]])[0]

    threads = [threading.Thread(target=one, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    for got, want in zip(results, serial):
        check(got["ok"] and got["choice"] == want["choice"]
              and got["time_s"] == want["time_s"],
              "concurrent distinct != serial", (got, want))
    print("distinct-vs-serial bit-identity OK")


def check_telemetry_consistency():
    service, telemetry = ToyService(), Telemetry()
    frontend = Frontend(service, telemetry, workers=4)
    addr = frontend.addr
    script = ["query setting=tele mem=3.0 batch=1",
              "frobnicate the planner",
              "query setting=nope mem=4 batch=1"]
    barrier = threading.Barrier(6)

    def one():
        barrier.wait()
        r = client(addr, script)
        check(r[0]["ok"], "good query failed", r[0])
        check(r[1]["error"] == "bad-request", "junk not rejected", r[1])
        check(r[2]["error"] == "unknown-setting", "bad setting", r[2])

    threads = [threading.Thread(target=one) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    frontend.shutdown()
    frontend.join()
    check(telemetry.get("requests") == 18, "3 lines x 6 conns",
          telemetry.to_json())
    check(telemetry.get("queries") == 12, "parsed queries",
          telemetry.to_json())
    check(telemetry.get("bad_requests") == 6, "junk lines",
          telemetry.to_json())
    check(telemetry.get("rejected") == 6, "unknown settings",
          telemetry.to_json())
    check(telemetry.batch_latency.count + telemetry.sweep_latency.count
          + telemetry.replan_latency.count == telemetry.get("queries"),
          "histogram counts (all three lanes) == queries",
          telemetry.to_json())
    check(service.stats["hits"] + service.stats["remote_hits"]
          + service.stats["misses"]
          == telemetry.get("queries") - telemetry.get("rejected"),
          "hits + remote_hits + misses == validated queries",
          (service.stats, telemetry.to_json()))
    check(service.stats["planner_runs"] == 1,
          "6 identical good queries -> one run", service.stats)
    print("telemetry consistency OK")


def check_framing():
    service, telemetry = ToyService(), Telemetry()
    frontend = Frontend(service, telemetry, workers=1, idle_timeout=0.2)
    addr = frontend.addr
    # oversized line: structured error, then hangup
    with socket.create_connection(addr, timeout=30) as s:
        s.sendall(b"x" * (64 * 1024))
        f = s.makefile("rb")
        doc = json.loads(f.readline())
        check(doc["error"] == "bad-request", "oversized line", doc)
        check(f.read() == b"", "socket closes after oversized line")
    # idle connection: timeout error, worker survives
    with socket.create_connection(addr, timeout=30) as s:
        f = s.makefile("rb")
        doc = json.loads(f.readline())
        check(doc["error"] == "timeout", "idle timeout", doc)
        check(f.read() == b"", "socket closes after idle timeout")
    check(telemetry.get("conn_timeouts") == 1, "timeout counted")
    stats = client(addr, ["stats"])[0]
    check(stats["kind"] == "stats", "the 1-worker pool is not wedged")
    frontend.shutdown()
    frontend.join()
    print("framing (oversized + idle timeout) OK")


def check_shutdown():
    service, telemetry = ToyService(), Telemetry()
    frontend = Frontend(service, telemetry, workers=2)
    addr = frontend.addr
    r = client(addr, ["query setting=bye mem=2.0 batch=1", "shutdown"])
    check(r[0]["ok"], "in-flight work completes before the ack", r[0])
    check(r[1] == {"kind": "shutdown", "ok": True}, "shutdown ack", r[1])
    frontend.join()
    try:
        with socket.create_connection(addr, timeout=2) as s:
            s.settimeout(2)
            check(s.makefile("rb").readline() == b"",
                  "no worker serves after shutdown")
    except OSError:
        pass  # refused outright: equally fine
    print("graceful shutdown OK")


def check_cache_tier():
    # cross-instance sharing through the second tier
    ch = CacheHandler(capacity=64)
    cache_fe = Frontend(None, Telemetry(), workers=2, handler=ch.handle)
    addr = "%s:%d" % cache_fe.addr
    a = ToyService(tier=RemoteTier(addr, deadline_s=0.25))
    qs = [(f"share{i}", 2.0 + i, 1 + i % 3) for i in range(4)]
    base = [toy_plan(s, m, b) for s, m, b in qs]
    for (s, m, b), want in zip(qs, base):
        got = a.query(s, m, b)
        check(got["choice"] == want["choice"]
              and got["time_s"] == want["time_s"],
              "instance A must match the remote-less planner",
              (got, want))
    check(a.tier.flush(5.0), "write-behind must drain")
    entries = json.loads(ch.handle("stats")[0])["entries"]
    check(entries == 4, "every plan landed in the tier", entries)
    b_svc = ToyService(tier=RemoteTier(addr, deadline_s=0.25))
    for (s, m, b), want in zip(qs, base):
        got = b_svc.query(s, m, b)
        check(got["source"] == "remote"
              and got["choice"] == want["choice"]
              and got["time_s"] == want["time_s"],
              "instance B must be served bit-identically from the tier",
              (got, want))
    check(b_svc.stats["planner_runs"] == 0, "B never planned",
          b_svc.stats)
    check(b_svc.stats["remote_hits"] == 4
          and b_svc.stats["misses"] == 0,
          "a remote hit reclassifies the provisional miss", b_svc.stats)
    # a lying entry under a real key quarantines, never answers
    req = canonical_req("poison", round(2.0, 9), 1)
    ch.handle("put " + json.dumps(
        {"schema": ENTRY_SCHEMA, "req": req, "choice": [0] * 12,
         "time_s": 1.0, "peak": 1.0}))
    want = toy_plan("poison", 2.0, 1)
    got = b_svc.query("poison", 2.0, 1)
    check(got["choice"] == want["choice"]
          and got["time_s"] == want["time_s"],
          "a lying cache entry must never change a plan", got)
    check(b_svc.stats["remote_quarantined"] == 1,
          "and it must quarantine", b_svc.stats)
    # a dead remote is invisible: same answers, failures counted,
    # breaker trips and then skips for free
    dead = socket.create_server(("127.0.0.1", 0))
    dead_addr = "%s:%d" % dead.getsockname()
    dead.close()
    tier_d = RemoteTier(dead_addr, deadline_s=0.05, threshold=2,
                        cooldown_s=30.0)
    d = ToyService(tier=tier_d)
    for (s, m, b), want in zip(qs, base):
        got = d.query(s, m, b)
        check(got["choice"] == want["choice"]
              and got["time_s"] == want["time_s"],
              "a dead tier must be invisible to answers", (got, want))
    check(d.stats["planner_runs"] == 4, "every query planned locally",
          d.stats)
    check(tier_d.get_counter("remote_errors")
          + tier_d.get_counter("remote_timeouts") >= 2,
          "failures must be counted", tier_d.counters)
    check(tier_d.get_counter("breaker_open") == 1
          and tier_d.breaker_state() == "open", "breaker must trip",
          tier_d.counters)
    t0 = time.monotonic()
    for _ in range(50):
        check(tier_d.get("anything")[0] == "skipped",
              "an open breaker skips")
    check(time.monotonic() - t0 < 0.5,
          "an open breaker must cost ~nothing per query")
    cache_fe.shutdown()
    cache_fe.join()
    print("cache tier mirror OK: shared, quarantined, "
          "dead-remote-proof")


def arg_value(argv, flag, default=None):
    if flag in argv:
        i = argv.index(flag)
        if i + 1 < len(argv):
            return argv[i + 1]
    return default


def main():
    argv = sys.argv[1:]
    if "--cache-serve" in argv:
        handler = CacheHandler(int(arg_value(argv, "--cache-cap",
                                             4096)))
        frontend = Frontend(None, Telemetry(), workers=4,
                            handler=handler.handle)
        print(json.dumps({"addr": "%s:%d" % frontend.addr,
                          "kind": "listening", "ok": True}),
              flush=True)
        frontend.join()
        return
    if "--serve" in argv:
        tier = None
        remote = arg_value(argv, "--remote")
        if remote:
            deadline_ms = int(arg_value(argv, "--remote-deadline-ms",
                                        5))
            tier = RemoteTier(remote,
                              deadline_s=max(deadline_ms, 1) / 1000.0)
        frontend = Frontend(ToyService(tier=tier), Telemetry(),
                            workers=8)
        print(json.dumps({"addr": "%s:%d" % frontend.addr,
                          "kind": "listening", "ok": True}),
              flush=True)
        frontend.join()
        return
    check_channel()
    check_histogram()
    service, telemetry = ToyService(), Telemetry()
    frontend = Frontend(service, telemetry, workers=8)
    check_coalescing(frontend)
    check_distinct_vs_serial(frontend)
    frontend.shutdown()
    frontend.join()
    check_telemetry_consistency()
    check_framing()
    check_shutdown()
    check_cache_tier()
    print("OK: all frontend-mirror checks passed")


if __name__ == "__main__":
    main()
