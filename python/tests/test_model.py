"""L2 correctness: GPT model shapes, packing round-trip, gradient checks,
Adam semantics (full-vector vs per-shard equivalence = ZeRO's partitioned
optimizer), and loss-decreases smoke training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

CFG = M.GPTConfig(name="test", vocab=64, seq=16, layers=2, hidden=32, heads=2)


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), CFG)


def tokens(key, b=2, cfg=CFG):
    return jax.random.randint(jax.random.PRNGKey(key), (b, cfg.seq + 1),
                              0, cfg.vocab)


class TestPacking:
    def test_roundtrip(self, params):
        packed = M.pack(params, CFG, pad_to=8)
        back = M.unpack(packed, CFG)
        for name in M.LEAF_ORDER:
            np.testing.assert_array_equal(back[name], params[name])

    def test_packed_len_padding(self):
        raw = sum(e["size"] for e in M.layout(CFG))
        assert M.packed_len(CFG, pad_to=8) % 8 == 0
        assert M.packed_len(CFG, pad_to=8) - raw < 8

    def test_layout_matches_param_count(self):
        assert sum(e["size"] for e in M.layout(CFG)) == CFG.param_count()

    def test_layout_offsets_contiguous(self):
        off = 0
        for e in M.layout(CFG):
            assert e["offset"] == off
            off += e["size"]

    @given(pad=st.sampled_from([1, 2, 4, 8, 16]))
    @settings(max_examples=5, deadline=None)
    def test_pad_tail_is_zero(self, pad):
        p = M.init_params(jax.random.PRNGKey(1), CFG)
        packed = M.pack(p, CFG, pad_to=pad)
        raw = CFG.param_count()
        assert np.all(np.asarray(packed[raw:]) == 0)


class TestForward:
    def test_logits_shape(self, params):
        toks = tokens(0)[:, :-1]
        logits = M.forward(params, toks, CFG)
        assert logits.shape == (2, CFG.seq, CFG.vocab)

    def test_loss_finite_and_near_uniform_at_init(self, params):
        packed = M.pack(params, CFG, pad_to=8)
        loss = M.loss_fn(packed, tokens(1), CFG)
        assert np.isfinite(loss)
        # tied-embedding init: loss should be near ln(V)
        assert abs(float(loss) - np.log(CFG.vocab)) < 1.0

    def test_causality(self, params):
        """Changing a future token must not change past logits."""
        toks = np.asarray(tokens(2, b=1)[:, :-1])
        logits1 = M.forward(params, jnp.asarray(toks), CFG)
        toks2 = toks.copy()
        toks2[0, -1] = (toks2[0, -1] + 1) % CFG.vocab
        logits2 = M.forward(params, jnp.asarray(toks2), CFG)
        np.testing.assert_allclose(logits1[0, :-1], logits2[0, :-1],
                                   rtol=1e-5, atol=1e-5)

    def test_batch_invariance(self, params):
        t1, t2 = tokens(3, b=1)[:, :-1], tokens(4, b=1)[:, :-1]
        both = jnp.concatenate([t1, t2])
        lb = M.forward(params, both, CFG)
        l1 = M.forward(params, t1, CFG)
        np.testing.assert_allclose(lb[0], l1[0], rtol=1e-4, atol=1e-4)


class TestGradients:
    def test_grad_matches_finite_difference(self, params):
        packed = M.pack(params, CFG, pad_to=8)
        toks = tokens(5)
        loss, grads = M.grad_step(packed, toks, CFG)
        assert grads.shape == packed.shape
        # probe a few coordinates with central differences
        rng = np.random.RandomState(0)
        idxs = rng.choice(CFG.param_count(), size=6, replace=False)
        eps = 1e-3
        for i in idxs:
            e = jnp.zeros_like(packed).at[i].set(eps)
            lp = M.loss_fn(packed + e, toks, CFG)
            lm = M.loss_fn(packed - e, toks, CFG)
            fd = (lp - lm) / (2 * eps)
            np.testing.assert_allclose(grads[i], fd, rtol=0.15, atol=2e-3)

    def test_grad_zero_on_padding(self, params):
        packed = M.pack(params, CFG, pad_to=8)
        _, grads = M.grad_step(packed, tokens(6), CFG)
        raw = CFG.param_count()
        assert np.all(np.asarray(grads[raw:]) == 0)

    def test_grads_deterministic(self, params):
        packed = M.pack(params, CFG, pad_to=8)
        _, g1 = M.grad_step(packed, tokens(7), CFG)
        _, g2 = M.grad_step(packed, tokens(7), CFG)
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


class TestAdam:
    def test_sharded_equals_full(self):
        """ZeRO's partitioned optimizer: updating N shards independently
        must equal updating the full vector (elementwise optimizer)."""
        p = jax.random.normal(jax.random.PRNGKey(0), (64,))
        g = jax.random.normal(jax.random.PRNGKey(1), (64,))
        m = jnp.zeros(64)
        v = jnp.zeros(64)
        step = jnp.int32(3)
        full = M.adam_update(p, g, m, v, step)
        for n in (2, 4, 8):
            sz = 64 // n
            parts = [M.adam_update(p[i*sz:(i+1)*sz], g[i*sz:(i+1)*sz],
                                   m[i*sz:(i+1)*sz], v[i*sz:(i+1)*sz], step)
                     for i in range(n)]
            for j in range(3):
                got = jnp.concatenate([pt[j] for pt in parts])
                np.testing.assert_allclose(got, full[j], rtol=1e-6, atol=1e-7)

    def test_descends_on_quadratic(self):
        p = jnp.ones(8) * 5.0
        m = jnp.zeros(8)
        v = jnp.zeros(8)
        for t in range(1, 200):
            g = 2 * p  # grad of ||p||^2
            p, m, v = M.adam_update(p, g, m, v, jnp.int32(t),
                                    M.AdamConfig(lr=0.05))
        assert float(jnp.max(jnp.abs(p))) < 0.5


class TestTraining:
    def test_loss_decreases(self):
        """Few steps of full-batch Adam on a fixed batch must overfit."""
        cfg = CFG
        params = M.init_params(jax.random.PRNGKey(2), cfg)
        packed = M.pack(params, cfg, pad_to=8)
        m = jnp.zeros_like(packed)
        v = jnp.zeros_like(packed)
        toks = tokens(8, b=4)
        step_fn = jax.jit(lambda p, t: M.grad_step(p, t, cfg))
        first = None
        for t in range(1, 31):
            loss, grads = step_fn(packed, toks)
            if first is None:
                first = float(loss)
            packed, m, v = M.adam_update(packed, grads, m, v, jnp.int32(t),
                                         M.AdamConfig(lr=1e-3))
        assert float(loss) < first * 0.8, (first, float(loss))
