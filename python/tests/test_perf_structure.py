"""L1 performance *structure* checks (DESIGN.md §Perf).

interpret=True Pallas gives CPU-numpy timings that say nothing about TPU
performance, so the perf contract for the kernels is structural and
analytical:

* VMEM footprint of every kernel instantiation used by the models stays
  under the 16 MiB budget (with double-buffering headroom);
* the operator-splitting schedule's footprint shrinks ~1/g while its
  arithmetic intensity (MXU utilization proxy) stays within 2x of the
  unsplit kernel;
* block shapes are MXU-aligned (multiples of 8x128 lanes) where the
  problem allows.
"""

import pytest

from compile.kernels.split_matmul import vmem_footprint_bytes
from compile import model as M

# CI runs `-m "not perf"`: these checks are analytical (no TPU), but they
# sweep every model config and don't gate correctness.
pytestmark = pytest.mark.perf

VMEM_BUDGET = 16 * 1024 * 1024  # bytes per core
DOUBLE_BUFFER = 2  # in/out staging headroom


def arithmetic_intensity(m, n, k, g):
    """FLOPs per HBM byte for one slice step of the split matmul."""
    ks = k // max(g, 1)
    flops = 2 * m * ks * n
    bytes_moved = 4 * (m * ks + ks * n)  # stream x-slice + w-slice
    return flops / bytes_moved


class TestVmemBudget:
    @pytest.mark.parametrize("cfg_name", list(M.CONFIGS))
    def test_model_matmuls_fit_vmem(self, cfg_name):
        """Every kmatmul instantiation in the GPT forward, at its actual
        shapes and the config's slice granularity, fits VMEM."""
        cfg = M.CONFIGS[cfg_name]
        rows = cfg.seq * 4  # batch_per_worker upper bound x seq
        g = cfg.slice_granularity
        shapes = [
            (rows, cfg.hidden, 3 * cfg.hidden),   # qkv
            (rows, cfg.hidden, cfg.hidden),       # proj
            (rows, cfg.hidden, 4 * cfg.hidden),   # mlp up
            (rows, 4 * cfg.hidden, cfg.hidden),   # mlp down
        ]
        for (m, k, n) in shapes:
            fp = vmem_footprint_bytes(m, n, k, g)
            assert fp * DOUBLE_BUFFER < VMEM_BUDGET * 64, (
                # CPU-era shapes are big; the real bound applies to the
                # tiled kernel below — this asserts the *scaling* contract
                f"{cfg_name} {m}x{k}x{n}/g{g}: {fp / 2**20:.1f} MiB"
            )

    def test_tiled_kernel_fits_vmem_strictly(self):
        """The MXU-shaped matmul_tiled blocks (128x128x128) are the
        production tiling: footprint must fit the real 16 MiB with
        double-buffering."""
        fp = vmem_footprint_bytes(128, 128, 128, 1)
        assert fp * DOUBLE_BUFFER < VMEM_BUDGET
        # even a 512-wide N stripe fits
        fp512 = (128 * 128 + 128 * 512 + 128 * 512) * 4
        assert fp512 * DOUBLE_BUFFER < VMEM_BUDGET

    def test_splitting_scales_footprint_down(self):
        base = vmem_footprint_bytes(1024, 4096, 8192, 1)
        for g in [2, 4, 8, 16]:
            fp = vmem_footprint_bytes(1024, 4096, 8192, g)
            # weight+activation slices shrink ~1/g; accumulator is constant
            assert fp < base, f"g={g}"
        g16 = vmem_footprint_bytes(1024, 4096, 8192, 16)
        acc_only = 1024 * 4096 * 4
        assert g16 - acc_only < (base - acc_only) / 8


class TestMxuUtilizationProxy:
    def test_intensity_stays_high_under_splitting(self):
        """Splitting must not turn the matmul memory-bound: arithmetic
        intensity at g=16 stays within 2x of unsplit."""
        base = arithmetic_intensity(1024, 4096, 8192, 1)
        split = arithmetic_intensity(1024, 4096, 8192, 16)
        assert split > base / 2.0, (base, split)
        # and both are comfortably above the bf16 MXU roofline knee
        # (~240 FLOPs/byte on TPUv4-era HBM); fp32 CPU-era bound is lower,
        # we assert > 128 as the structural floor
        assert split > 128

    def test_k_split_preserves_intensity_exactly(self):
        """A strength of the K-sliced schedule: per-step arithmetic
        intensity is 2·m·ks·n / 4(m·ks + ks·n) = m·n/2(m+n) — independent
        of the slice size (the accumulator never leaves VMEM). Splitting
        costs launch latency (Figure 7's small-op slowdown), never
        bandwidth efficiency."""
        base = arithmetic_intensity(256, 768, 768, 1)
        split = arithmetic_intensity(256, 768, 768, 16)
        assert abs(split - base) < 1e-9

    def test_small_matmuls_have_lower_intensity(self):
        """Small hidden sizes are inherently closer to memory-bound —
        the roofline reason the planner's γ treats them uniformly but the
        latency term penalizes slicing them."""
        small = arithmetic_intensity(256, 768, 768, 1)
        large = arithmetic_intensity(1024, 8192, 8192, 1)
        assert small < large / 3


class TestHloArtifactStructure:
    """Artifact-level checks: the AOT HLO keeps the schedules we authored
    (no silent re-materialization into one giant fused matmul)."""

    @pytest.fixture(scope="class")
    def manifest(self):
        import json
        import os
        path = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts", "manifest.json")
        if not os.path.exists(path):
            pytest.skip("run `make artifacts` first")
        with open(path) as f:
            return json.load(f)

    def test_split_demo_sizes_scale_with_granularity(self, manifest):
        """Higher granularity = more grid steps = more HLO ops; check the
        artifacts actually differ (the schedule survived lowering)."""
        sizes = {
            g: manifest["files"][f"split_demo_g{g}.hlo.txt"]["bytes"]
            for g in [1, 2, 4, 8]
        }
        assert sizes[8] > sizes[1], sizes

    def test_grad_step_io_shapes(self, manifest):
        for name, cfg in manifest["configs"].items():
            f = manifest["files"][f"{name}_grad_step.hlo.txt"]
            (pname, pshape, pdt), (tname, tshape, tdt) = f["inputs"]
            assert pshape == [cfg["packed_len"]]
            assert tshape == [cfg["batch_per_worker"], cfg["seq"] + 1]
            assert (pdt, tdt) == ("f32", "i32")
            loss, grads = f["outputs"]
            assert grads[1] == [cfg["packed_len"]]
