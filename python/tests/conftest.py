import importlib.util
import os
import sys

# Tests import `compile.*` relative to python/ regardless of invocation dir.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _missing(mod):
    return importlib.util.find_spec(mod) is None


# Skip gracefully when optional heavyweight deps are absent (CI installs
# JAX best-effort; offline containers may lack hypothesis too).
collect_ignore = []
if _missing("jax"):
    collect_ignore += [
        "test_kernels.py", "test_model.py", "test_perf_structure.py",
    ]
elif _missing("hypothesis"):
    collect_ignore += ["test_kernels.py", "test_model.py"]
