"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes / granularities / dtypes; assert_allclose against
ref.py.  This is the gate `make artifacts` quality rests on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import split_matmul, matmul_tiled, attention, layernorm
from compile.kernels.attention import attention_mha
from compile.kernels.split_matmul import vmem_footprint_bytes
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=dtype)


# ---------------------------------------------------------------------------
# split_matmul
# ---------------------------------------------------------------------------

class TestSplitMatmul:
    @settings(max_examples=25, deadline=None)
    @given(
        m=st.sampled_from([1, 3, 8, 32, 57]),
        n=st.sampled_from([1, 4, 16, 64, 96]),
        ks=st.sampled_from([8, 16, 24]),
        g=st.sampled_from([1, 2, 4, 8]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_plain_matmul(self, m, n, ks, g, seed):
        k = ks * g
        x = rand(seed, (m, k))
        w = rand(seed + 1, (k, n))
        got = split_matmul(x, w, granularity=g)
        want = ref.matmul_ref(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(g=st.sampled_from([1, 2, 4, 8, 16]), seed=st.integers(0, 2**16))
    def test_matches_figure4_slice_and_sum(self, g, seed):
        """Kernel == the paper's literal slice/sequential/sum definition."""
        x = rand(seed, (16, 64))
        w = rand(seed + 1, (64, 32))
        got = split_matmul(x, w, granularity=g)
        want = ref.split_matmul_ref(x, w, g)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_granularity_must_divide(self):
        x, w = rand(0, (4, 10)), rand(1, (10, 4))
        with pytest.raises(AssertionError):
            split_matmul(x, w, granularity=3)

    def test_granularity_zero_means_no_split(self):
        # Paper's figures use granularity 0 for "no splitting".
        x, w = rand(0, (4, 8)), rand(1, (8, 4))
        got = split_matmul(x, w, granularity=0)
        np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-5)

    def test_vmem_footprint_monotone_in_granularity(self):
        """More slices -> strictly less on-chip footprint (Fig 7 memory)."""
        fps = [vmem_footprint_bytes(256, 1024, 4096, g) for g in [1, 2, 4, 8, 16]]
        assert all(a > b for a, b in zip(fps, fps[1:]))

    def test_bf16_supported(self):
        """bf16 in/out works; tolerance reflects bf16 accumulation."""
        x = rand(7, (32, 64)).astype(jnp.bfloat16)
        w = rand(8, (64, 32)).astype(jnp.bfloat16)
        got = split_matmul(x, w, granularity=4).astype(np.float32)
        want = np.asarray(
            jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)))
        scale = np.max(np.abs(want))
        np.testing.assert_allclose(got, want, atol=0.02 * scale)


class TestMatmulTiled:
    @settings(max_examples=20, deadline=None)
    @given(
        mt=st.sampled_from([1, 2, 4]),
        nt=st.sampled_from([1, 2, 3]),
        kt=st.sampled_from([1, 2, 4]),
        bm=st.sampled_from([8, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, mt, nt, kt, bm, seed):
        m, n, k = mt * bm, nt * 16, kt * 16
        x, w = rand(seed, (m, k)), rand(seed + 1, (k, n))
        got = matmul_tiled(x, w, bm=bm, bn=16, bk=16)
        np.testing.assert_allclose(got, ref.matmul_ref(x, w),
                                   rtol=1e-5, atol=1e-5)

    def test_block_clamped_to_problem(self):
        x, w = rand(0, (8, 8)), rand(1, (8, 8))
        got = matmul_tiled(x, w)  # default blocks 128 > 8 -> clamped
        np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-5)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

class TestAttention:
    @settings(max_examples=20, deadline=None)
    @given(
        s=st.sampled_from([16, 32, 64, 128]),
        d=st.sampled_from([8, 16, 32]),
        bq=st.sampled_from([8, 16, 64]),
        causal=st.booleans(),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, s, d, bq, causal, seed):
        if s % min(bq, s) != 0:
            return
        q = rand(seed, (s, d))
        k = rand(seed + 1, (s, d))
        v = rand(seed + 2, (s, d))
        got = attention(q, k, v, causal=causal, block_q=bq)
        want = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_causal_first_row_is_v0(self):
        """Row 0 attends only to position 0 under the causal mask."""
        q, k, v = rand(0, (16, 8)), rand(1, (16, 8)), rand(2, (16, 8))
        out = attention(q, k, v, causal=True, block_q=8)
        np.testing.assert_allclose(out[0], v[0], rtol=1e-5, atol=1e-6)

    def test_block_size_invariance(self):
        q, k, v = rand(3, (64, 16)), rand(4, (64, 16)), rand(5, (64, 16))
        outs = [attention(q, k, v, block_q=bq) for bq in (8, 16, 32, 64)]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-6)

    def test_mha_vmap(self):
        h, s, d = 4, 32, 8
        q, k, v = rand(6, (h, s, d)), rand(7, (h, s, d)), rand(8, (h, s, d))
        got = attention_mha(q, k, v)
        want = jnp.stack([ref.attention_ref(q[i], k[i], v[i])
                          for i in range(h)])
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------

class TestLayerNorm:
    @settings(max_examples=20, deadline=None)
    @given(
        r=st.sampled_from([8, 32, 128, 256]),
        h=st.sampled_from([16, 64, 257]),
        br=st.sampled_from([8, 32, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, r, h, br, seed):
        if r % min(br, r) != 0:
            return
        x = rand(seed, (r, h))
        g = rand(seed + 1, (h,)) * 0.1 + 1.0
        b = rand(seed + 2, (h,)) * 0.1
        got = layernorm(x, g, b, block_rows=br)
        want = ref.layernorm_ref(x, g, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_output_normalized(self):
        x = rand(9, (64, 128)) * 10 + 3
        out = layernorm(x, jnp.ones(128), jnp.zeros(128))
        np.testing.assert_allclose(np.mean(out, -1), 0, atol=1e-4)
        np.testing.assert_allclose(np.std(out, -1), 1, atol=1e-3)
