#!/usr/bin/env python3
"""End-to-end driver for the osdp plan-service socket front-end.

CI's `serve-concurrency` job runs this against the **release binary**
(`--bin target/release/osdp`): it starts `osdp serve --listen
127.0.0.1:0 --workers 8 --metrics`, discovers the ephemeral port from
the first stdout line, and then proves the served-concurrency contract
through the wire:

1. 8 parallel clients sending the **identical** query observe exactly
   one planner execution (asserted via the `stats` verb, not by peeking
   at internals) and receive bit-identical answers;
2. concurrent **distinct** queries match their serial re-ask bit for bit
   (and the re-asks are cache hits);
3. malformed lines come back as structured `bad-request` errors;
4. telemetry is consistent: histogram counts == queries, and
   `hits + misses == queries - rejected`;
5. `shutdown` acks, drains, and the server process exits 0.

The same assertions run against the pure-python mirror
(`--mirror`, python/mirror/frontend_mirror.py --serve) in containers
without a Rust toolchain, or against an already-running server
(`--addr host:port` — skips the process-lifecycle checks).

`--chaos [--fault-seed N]` starts the server under a deterministic
`OSDP_FAULTS` plan (panicking searches, slow searches, cache I/O
errors, mid-line socket resets, and the remote-tier fault sites) and
replaces the exact-count phases with the survival contract CI's
`fault-injection` job pins:

1. the server stays responsive through the whole run (every request
   eventually succeeds on retry — individual deaths are the point);
2. `worker_restarts` goes positive: injected panics really crossed
   the pool and the pool really resurrected;
3. the telemetry invariants hold *exactly* under chaos — histogram
   counts (batch + sweep + replan) == queries, hits + remote_hits +
   misses == queries − rejected;
4. the observability surface holds under the same chaos (binary only):
   the `metrics` page parses and agrees with the `stats` verb, and
   every trace in the ring is a closed tree;
5. `shutdown` is acknowledged (or a torn ack still shuts down) and
   the process exits 0.

`--trace` (binary only) adds the observability phase: the server gets a
`--metrics-listen` scrape endpoint; every query answer must carry a
`trace_id` that resolves through the `trace` verb to a complete span
tree (root `query` span, parents preceding children, hex `time_bits`
convergence events), the `metrics` verb's Prometheus page must agree
with the `stats` verb counter for counter, and an HTTP `GET` scrape of
the endpoint must return the same page without perturbing anything.

`--tier` starts a standalone cache server (`osdp cache-serve`, or the
mirror's `--cache-serve`) plus **two** plan-service instances attached
to it via `--remote`, and proves the second-tier contract through the
wire: instance A plans cold and write-behind-publishes; once the tier
holds every entry, instance B answers the same queries bit-identically
with **zero** planner runs, all `source:"remote"`, and the invariant
`hits + remote_hits + misses == queries - rejected` holds on both.
`--tier --chaos` runs the survival contract on both instances with the
remote fault sites firing — remote faults must demote to local misses,
never change an answer, and never wedge a shutdown.

Stdlib only; exits non-zero on any mismatch.
"""

import argparse
import json
import os
import socket
import subprocess
import sys
import threading
import time

SETTING = "gpt:3000,64,6,192,4"
IDENTICAL = f"query setting={SETTING} mem=4 batch=2 threads=1"
DISTINCT = [
    f"query setting={SETTING} mem={mem} batch={b} threads=1"
    for mem, b in [(2, 1), (3, 1), (4, 1), (6, 2), (2.5, 2), (5, 3)]
]


def fail(msg, ctx=""):
    print("FAIL:", msg)
    if ctx != "":
        print("  ctx:", ctx)
    sys.exit(1)


def check(cond, msg, ctx=""):
    if not cond:
        fail(msg, ctx)


def client(addr, lines, timeout=300.0):
    """One connection; one JSON response line per request line."""
    out = []
    with socket.create_connection(addr, timeout=timeout) as s:
        f = s.makefile("rwb")
        for line in lines:
            f.write(line.encode() + b"\n")
            f.flush()
            resp = f.readline()
            check(resp.endswith(b"\n"),
                  "response not newline-framed", resp)
            out.append(json.loads(resp))
    return out


def try_request(addr, line, timeout=30.0):
    """One chaos-tolerant request: None on connect failure, EOF,
    truncation (a torn, non-newline-terminated fragment is exactly
    what an injected sock-reset produces), or unparsable JSON."""
    try:
        with socket.create_connection(addr, timeout=timeout) as s:
            f = s.makefile("rwb")
            f.write(line.encode() + b"\n")
            f.flush()
            resp = f.readline()
    except OSError:
        return None
    if not resp.endswith(b"\n"):
        return None
    try:
        return json.loads(resp)
    except ValueError:
        return None


# Counters compared between the `stats` verb and the Prometheus page.
# Net counters like `requests` are deliberately excluded: serving the
# two verbs itself moves them between the snapshots; everything listed
# here only moves when a query is dispatched.
SERVICE_FIELDS = [
    "hits", "misses", "inserts", "evictions", "coalesced",
    "planner_runs", "warm_seeded", "persist_errors", "replans",
    "replan_repairs", "cache_write_retries", "remote_hits",
    "remote_errors", "breaker_open",
]
NET_FIELDS = ["queries", "rejected", "infeasible", "bad_requests"]
LANES = ["batch", "sweep", "replan"]


def parse_prometheus(page):
    """`name{labels}` -> value; fails on anything that is not a
    comment, a blank line, or `series value` (the "exposition parses"
    invariant)."""
    out = {}
    for line in page.splitlines():
        if not line or line.startswith("#"):
            continue
        series, sep, value = line.rpartition(" ")
        check(sep == " ", "metric lines are 'series value'", line)
        try:
            v = float(value)
        except ValueError:
            fail("unparseable metric value", line)
        check(series not in out, "duplicate series", series)
        out[series] = v
    return out


def lane_count(tele, shape):
    return tele["latency"].get(shape, {"count": 0})["count"]


def stats_subset(stats):
    """The fields `check_metrics_match_stats` compares, extracted from
    a `stats` document — used to detect whether anything moved between
    two snapshots (straggler chaos threads)."""
    tele = stats["telemetry"]
    sub = {f: stats.get(f, 0) for f in SERVICE_FIELDS}
    sub.update({f"net:{c}": tele[c] for c in NET_FIELDS})
    sub.update({f"lane:{s}": lane_count(tele, s) for s in LANES})
    sub["cache_entries"] = stats.get("cache_entries")
    sub["breaker"] = stats.get("breaker")
    return sub


def check_metrics_match_stats(stats, page):
    """The Prometheus page must tell the same story as the `stats`
    verb, counter for counter."""
    m = parse_prometheus(page)
    tele = stats["telemetry"]
    for f in SERVICE_FIELDS:
        check(m.get(f"osdp_service_{f}_total") == stats.get(f, 0),
              f"stats/metrics disagree on {f!r}", stats)
    for c in NET_FIELDS:
        check(m.get(f"osdp_net_{c}_total") == tele[c],
              f"stats/metrics disagree on net {c!r}", stats)
    for s in LANES:
        series = f'osdp_latency_seconds_count{{shape="{s}"}}'
        check(m.get(series) == lane_count(tele, s),
              f"stats/metrics disagree on the {s} lane", stats)
    check(m.get("osdp_cache_entries") == stats.get("cache_entries"),
          "stats/metrics disagree on cache_entries", stats)
    breaker = stats.get("breaker")
    check(m.get(f'osdp_breaker_state{{state="{breaker}"}}') == 1,
          "the breaker gauge must be one-hot on the stats verb's state",
          stats)


def check_traces_closed(traces):
    """Every trace the ring kept must be a closed tree — chaos that
    kills a request mid-flight drops its trace entirely, it never
    reaches the ring half-built."""
    check(traces.get("kind") == "traces", "trace listing", traces)
    for t in traces.get("traces", []):
        check(t.get("complete") is True,
              "an incomplete trace escaped into the ring", t)


def chaos(addr, proc, deadline_s=120.0):
    """The fault-injected survival contract (driver side of the Rust
    integration test rust/tests/fault_injection.rs)."""
    deadline = time.monotonic() + deadline_s
    lines = [
        f"query setting={SETTING} mem={2.0 + 0.5 * (i % 4)} "
        f"batch={1 + i % 2} threads=1"
        for i in range(12)
    ]
    # a replan rides along so the replan latency lane is exercised under
    # the same fault plan (the mirror answers bad-request — also fine)
    lines.append(
        f"replan setting={SETTING} mem=2 batch=1 devices=8 threads=1 "
        "new-devices=4"
    )

    def ask(line):
        while True:
            doc = try_request(addr, line)
            if doc is not None:
                return doc
            check(time.monotonic() < deadline,
                  f"{line!r} never survived the fault plan")
            time.sleep(0.02)

    restarts, rounds, metrics_checked = 0, 0, 0
    while True:
        # a concurrent burst; individual requests may die to injected
        # faults — the server as a whole must keep answering
        threads = [threading.Thread(target=try_request, args=(addr, l))
                   for l in lines]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        stats = ask("stats")
        check(stats.get("kind") == "stats", "stats verb under chaos",
              stats)
        tele = stats["telemetry"]
        check(stats["hits"] + stats.get("remote_hits", 0)
              + stats["misses"]
              == tele["queries"] - tele["rejected"],
              "hits + remote_hits + misses == queries - rejected "
              "must survive chaos", stats)
        check(sum(lane_count(tele, s) for s in LANES)
              == tele["queries"],
              "every query observed exactly once, in exactly one lane, "
              "under chaos", stats)
        # the observability surface holds under the same chaos (binary
        # only — the mirror answers these verbs with bad-request). A
        # straggler burst thread could move a counter between the two
        # snapshots, so the cross-check only fires when a stats re-ask
        # confirms the window was quiet.
        metrics = ask("metrics")
        if metrics.get("kind") == "metrics":
            stats2 = ask("stats")
            if stats_subset(stats) == stats_subset(stats2):
                check_metrics_match_stats(stats2, metrics["text"])
                metrics_checked += 1
            traces = ask("trace")
            if traces.get("kind") == "traces":
                check_traces_closed(traces)
        restarts = tele.get("worker_restarts", 0)
        rounds += 1
        if restarts > 0 and rounds >= 2:
            break
        check(time.monotonic() < deadline,
              f"no worker restart after {rounds} rounds "
              "(injected panics are not reaching the pool)", stats)
    print(f"chaos OK: {rounds} rounds, {restarts} worker restarts, "
          "telemetry invariants exact, "
          f"{metrics_checked} stats/metrics cross-checks")

    # graceful shutdown despite resets: a torn ack still flips the
    # server-side flag, so on transport failure probe the listener
    while True:
        ack = try_request(addr, "shutdown")
        if ack is not None:
            check(ack == {"kind": "shutdown", "ok": True},
                  "shutdown ack under chaos", ack)
            break
        try:
            socket.create_connection(addr, timeout=2).close()
        except OSError:
            break  # already draining
        check(time.monotonic() < deadline, "shutdown never acknowledged")
        time.sleep(0.02)
    if proc is not None:
        rc = proc.wait(timeout=120)
        check(rc == 0, "server must exit 0 after chaos shutdown", rc)
    print("OK: fault-injected serve path held end to end")


def launch(args, env, extra=(), cache=False):
    """Start one server process (binary or mirror, plan service or
    cache server) and parse its listening banner. Returns
    (proc, (host, port), "host:port")."""
    if args.mirror:
        mirror = os.path.join(os.path.dirname(__file__), os.pardir,
                              "mirror", "frontend_mirror.py")
        mode = "--cache-serve" if cache else "--serve"
        cmd = [sys.executable, mirror, mode, *extra]
    elif cache:
        cmd = [args.bin, "cache-serve", "--listen", "127.0.0.1:0",
               *extra]
    else:
        cmd = [args.bin, "serve", "--listen", "127.0.0.1:0",
               "--workers", str(args.workers), "--metrics", *extra]
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, text=True,
                            env=env)
    banner = proc.stdout.readline()
    try:
        doc = json.loads(banner)
    except ValueError:
        fail("first stdout line is not JSON", banner)
    check(doc.get("kind") == "listening" and doc.get("ok") is True,
          "expected the listening banner", doc)
    host, port = doc["addr"].rsplit(":", 1)
    return proc, (host, int(port)), doc["addr"]


def shutdown_server(addr, proc, deadline_s=60.0):
    """Ask a server to shut down, tolerating torn acks (an injected
    sock-reset can tear the ack line; the flag still flips)."""
    deadline = time.monotonic() + deadline_s
    while True:
        ack = try_request(addr, "shutdown")
        if ack is not None:
            check(ack == {"kind": "shutdown", "ok": True},
                  "shutdown ack", ack)
            break
        try:
            socket.create_connection(addr, timeout=2).close()
        except OSError:
            break  # already draining
        check(time.monotonic() < deadline, "shutdown never acknowledged")
        time.sleep(0.02)
    if proc is not None:
        rc = proc.wait(timeout=120)
        check(rc == 0, "server must exit 0 after shutdown", rc)


def tier_run(args, env):
    """The second-tier contract: one cache server, two plan services
    sharing it."""
    cache_proc, cache_addr, cache_str = launch(args, env, cache=True)
    print(f"cache server listening on {cache_str}")
    extra = ["--remote", cache_str, "--remote-deadline-ms", "250"]
    a_proc, a_addr, a_str = launch(args, env, extra=extra)
    b_proc, b_addr, b_str = launch(args, env, extra=extra)
    print(f"plan services listening on {a_str} and {b_str}")

    if args.chaos:
        # survival contract on both instances, remote fault sites
        # firing against a real shared tier; then everything must
        # still shut down cleanly
        chaos(a_addr, a_proc)
        chaos(b_addr, b_proc)
        shutdown_server(cache_addr, cache_proc)
        print("OK: fault-injected two-tier serve path held end to end")
        return

    # ---- phase A: instance A plans cold and publishes write-behind
    cold = [client(a_addr, [line])[0] for line in DISTINCT]
    for r in cold:
        check(r.get("ok") is True, "cold query on A failed", r)
    a_stats = client(a_addr, ["stats"])[0]
    check(a_stats["planner_runs"] == len(DISTINCT),
          "A must have planned every distinct query", a_stats)
    check(a_stats.get("remote_hits") == 0
          and a_stats.get("remote_misses") == len(DISTINCT),
          "a fresh tier must miss for every A query", a_stats)
    deadline = time.monotonic() + 60.0
    while True:
        doc = try_request(cache_addr, "stats")
        if doc is not None and doc.get("entries") == len(DISTINCT):
            break
        check(time.monotonic() < deadline,
              "write-behind puts never landed in the tier", doc)
        time.sleep(0.02)
    print(f"phase A OK: {len(DISTINCT)} plans published to the tier")

    # ---- phase B: instance B answers from the tier, zero planning
    shared = [client(b_addr, [line])[0] for line in DISTINCT]
    for got, want in zip(shared, cold):
        check(got.get("ok") is True, "shared query on B failed", got)
        check(got.get("source") == "remote",
              "B must be served from the remote tier", got)
        check(got["choice"] == want["choice"]
              and got["time_s"] == want["time_s"],
              "cross-instance answers must be bit-identical",
              (got, want))
    b_stats = client(b_addr, ["stats"])[0]
    check(b_stats["planner_runs"] == 0,
          "B must never have run the planner", b_stats)
    check(b_stats.get("remote_hits") == len(DISTINCT)
          and b_stats["misses"] == 0,
          "every B query must reclassify as a remote hit", b_stats)
    check(b_stats.get("breaker") == "closed",
          "a healthy tier keeps the breaker closed", b_stats)
    for name, stats in (("A", a_stats), ("B", b_stats)):
        tele = stats["telemetry"]
        check(stats["hits"] + stats.get("remote_hits", 0)
              + stats["misses"]
              == tele["queries"] - tele["rejected"],
              f"hits + remote_hits + misses invariant on {name}",
              stats)
    print(f"phase B OK: {len(DISTINCT)} queries served from the tier, "
          "0 planner runs on B")

    # ---- teardown: all three processes exit 0
    shutdown_server(b_addr, b_proc)
    shutdown_server(a_addr, a_proc)
    shutdown_server(cache_addr, cache_proc)
    print("OK: second-tier sharing contract holds end to end")


def concurrent(addr, lines):
    """One thread + connection per line, released together."""
    barrier = threading.Barrier(len(lines))
    results = [None] * len(lines)

    def one(i):
        barrier.wait()
        results[i] = client(addr, [lines[i]])[0]

    threads = [threading.Thread(target=one, args=(i,))
               for i in range(len(lines))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=600)
        check(not t.is_alive(), "client thread hung")
    return results


def observability(addr, metrics_addr):
    """The --trace phase: trace ids resolve to complete span trees, the
    `metrics` verb agrees with `stats`, and the HTTP scrape endpoint
    serves the same page."""
    listing = client(addr, ["trace"])[0]
    check(listing.get("kind") == "traces", "trace listing", listing)
    if listing.get("enabled") is False:
        print("trace phase SKIP: tracing compiled out (no_trace build)")
        return

    # the cache-hit answer still carries a fresh trace id
    r = client(addr, [IDENTICAL])[0]
    tid = r.get("trace_id")
    check(isinstance(tid, str) and tid,
          "query answers must carry a trace id", r)
    doc = client(addr, [f"trace {tid}"])[0]
    check(doc.get("ok") is True, "trace id must resolve", doc)
    trace = doc["trace"]
    check(trace["id"] == tid and trace["complete"] is True,
          "a served query's trace must be a closed tree", trace)
    spans = trace["spans"]
    check(spans and spans[0]["name"] == "query"
          and spans[0]["parent"] is None,
          "the root span is the query itself", spans)
    for i, s in enumerate(spans):
        if i > 0:
            check(isinstance(s["parent"], (int, float))
                  and 0 <= s["parent"] < i,
                  "parents precede children in open order", spans)
        check(s["dur_s"] >= 0, "span durations are non-negative", s)
    names = [s["name"] for s in spans]
    check("cache" in names, "a served query touched the cache", names)
    for e in trace["timeline"]:
        check(e["source"] in ("greedy", "warm", "descent"),
              "timeline sources are the three incumbent origins", e)
        bits = e["time_bits"]
        check(isinstance(bits, str) and bits.startswith("0x")
              and len(bits) == 18, "time_bits are full-width hex", e)
        int(bits, 16)  # parses
    nf = client(addr, ["trace t999999-nope"])[0]
    check(nf.get("ok") is False and nf.get("error") == "not-found",
          "unknown trace ids miss structurally", nf)

    # one connection, so nothing moves between the two snapshots
    stats, metrics = client(addr, ["stats", "metrics"])
    check(metrics.get("kind") == "metrics", "metrics verb", metrics)
    check_metrics_match_stats(stats, metrics["text"])

    if metrics_addr is not None:
        with socket.create_connection(metrics_addr, timeout=30) as s:
            s.sendall(b"GET /metrics HTTP/1.0\r\n\r\n")
            data = b""
            while True:
                chunk = s.recv(65536)
                if not chunk:
                    break
                data += chunk
        text = data.decode()
        check(text.startswith("HTTP/1.0 200 OK\r\n"),
              "the scrape endpoint speaks HTTP", text[:80])
        check("text/plain; version=0.0.4" in text,
              "exposition content type", text[:200])
        body = text.split("\r\n\r\n", 1)[1]
        # the extra verbs above moved no query-driven counter, so the
        # stats snapshot still prices the scrape exactly
        check_metrics_match_stats(stats, body)
        print("trace phase OK: trace tree complete, metrics == stats "
              "(verb and HTTP scrape)")
    else:
        print("trace phase OK: trace tree complete, metrics == stats")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bin", help="osdp binary to start and drive")
    ap.add_argument("--addr", help="host:port of a running server")
    ap.add_argument("--mirror", action="store_true",
                    help="drive python/mirror/frontend_mirror.py")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--chaos", action="store_true",
                    help="run under a deterministic OSDP_FAULTS plan "
                         "and assert the survival contract instead of "
                         "the exact-count phases")
    ap.add_argument("--fault-seed", type=int, default=1117,
                    help="seed for the --chaos fault plan")
    ap.add_argument("--tier", action="store_true",
                    help="start a cache server plus two plan services "
                         "sharing it and assert the second-tier "
                         "contract")
    ap.add_argument("--trace", action="store_true",
                    help="add the observability phase: span trees via "
                         "the trace verb, metrics == stats, and the "
                         "--metrics-listen HTTP scrape endpoint")
    args = ap.parse_args()
    if args.trace and args.mirror:
        ap.error("--trace drives binary-only verbs; drop --mirror")
    if args.trace and (args.chaos or args.tier):
        ap.error("--trace extends the plain contract run; "
                 "drop --chaos/--tier")

    env = dict(os.environ)
    if args.chaos:
        spec = (
            f"seed:{args.fault_seed},panic:60000,slow:40000,slow-ms:1,"
            "cache-io:150000,sock-reset:40000"
        )
        if args.tier:
            spec += ",remote-slow:60000,remote-io:120000," \
                    "remote-garbage:60000"
        env["OSDP_FAULTS"] = spec
        print(f"chaos plan: {env['OSDP_FAULTS']}")

    if args.tier:
        if args.addr:
            ap.error("--tier starts its own servers; drop --addr")
        if not (args.bin or args.mirror):
            ap.error("one of --bin, --mirror is required")
        tier_run(args, env)
        return

    proc = None
    metrics_addr = None
    if args.addr:
        host, port = args.addr.rsplit(":", 1)
        addr = (host, int(port))
    else:
        if not (args.bin or args.mirror):
            ap.error("one of --bin, --addr, --mirror is required")
        extra = []
        if args.chaos and args.bin:
            # a disk cache so the injected cache-io faults actually
            # exercise the bounded-retry persistence path
            import tempfile
            extra = ["--cache-dir",
                     tempfile.mkdtemp(prefix="osdp-chaos-")]
        if args.trace:
            extra += ["--metrics-listen", "127.0.0.1:0"]
        proc, addr, addr_str = launch(args, env, extra=extra)
        print(f"server listening on {addr_str}")
        if args.trace:
            # the scrape endpoint's banner follows the listening line
            banner = proc.stdout.readline()
            try:
                doc = json.loads(banner)
            except ValueError:
                fail("second stdout line is not JSON", banner)
            check(doc.get("kind") == "metrics-listening"
                  and doc.get("ok") is True,
                  "expected the metrics-listening banner", doc)
            mhost, mport = doc["addr"].rsplit(":", 1)
            metrics_addr = (mhost, int(mport))
            print(f"metrics endpoint listening on {doc['addr']}")

    if args.chaos:
        chaos(addr, proc)
        return

    # ---- phase 1: 8 identical concurrent queries -> 1 planner run
    results = concurrent(addr, [IDENTICAL] * 8)
    for r in results:
        check(r.get("ok") is True, "identical query failed", r)
        check(r["choice"] == results[0]["choice"]
              and r["time_s"] == results[0]["time_s"],
              "concurrent identical answers must be bit-identical",
              (r, results[0]))
    stats = client(addr, ["stats"])[0]
    check(stats.get("planner_runs") == 1,
          "8 identical concurrent queries must run exactly ONE search",
          stats)
    check(stats.get("hits", 0) + stats.get("coalesced", 0) == 7,
          "everyone but the leader shares the flight", stats)
    print("phase 1 OK: 8 identical concurrent queries -> 1 planner run")

    # ---- phase 2: distinct concurrent queries vs serial re-asks
    conc = concurrent(addr, DISTINCT)
    serial = [client(addr, [line])[0] for line in DISTINCT]
    for got, want in zip(conc, serial):
        check(got.get("ok") is True, "distinct query failed", got)
        check(want.get("source") == "cache",
              "serial re-ask must be a cache hit", want)
        check(got["choice"] == want["choice"]
              and got["time_s"] == want["time_s"],
              "concurrent distinct != serial re-ask", (got, want))
    print(f"phase 2 OK: {len(DISTINCT)} distinct concurrent queries "
          "bit-identical to serial")

    # ---- phase 3: hostile lines are structured errors, not hangups
    hostile = client(addr, [
        "frobnicate the planner",
        "query setting=nope mem=4 batch=1",
    ])
    check(hostile[0].get("error") == "bad-request",
          "junk must be a structured bad-request", hostile[0])
    check(hostile[1].get("error") in ("unknown-setting", "bad-request"),
          "bad setting must be structurally rejected", hostile[1])
    print("phase 3 OK: hostile lines answered structurally")

    # ---- phase 4: telemetry consistency through the stats verb
    stats = client(addr, ["stats"])[0]
    tele = stats.get("telemetry")
    check(isinstance(tele, dict), "stats must carry telemetry", stats)
    queries = tele["queries"]
    expected = 8 + 2 * len(DISTINCT) + 1  # identical + conc/serial + bad
    check(queries == expected, "every dispatched query counted",
          (queries, expected, tele))
    check(sum(lane_count(tele, s) for s in LANES) == queries,
          "histogram counts == queries", tele)
    check(stats["hits"] + stats["misses"]
          == queries - tele["rejected"],
          "hits + misses == queries - rejected", stats)
    check(stats["planner_runs"] == 1 + len(DISTINCT),
          "one run per distinct cacheable query", stats)
    print("phase 4 OK: telemetry consistent "
          f"({queries} queries, {stats['planner_runs']} planner runs)")

    # ---- trace phase (--trace): span trees, metrics == stats, scrape
    if args.trace:
        observability(addr, metrics_addr)

    # ---- phase 5: graceful shutdown drains and exits cleanly
    final = client(addr, [IDENTICAL, "shutdown"])
    check(final[0].get("ok") is True and final[0]["source"] == "cache",
          "in-flight work served before the ack", final[0])
    check(final[1] == {"kind": "shutdown", "ok": True},
          "shutdown ack", final[1])
    if proc is not None:
        rc = proc.wait(timeout=120)
        check(rc == 0, "server must exit 0 after shutdown", rc)
    print("phase 5 OK: graceful shutdown")
    print("OK: served-concurrency contract holds end to end")


if __name__ == "__main__":
    main()
