//! End-to-end validation driver (DESIGN.md §7): train a GPT for real across
//! simulated devices — real Pallas/JAX math through PJRT, real bytes
//! through the ring collectives — and log the loss curve.
//!
//! Defaults to the `e2e` config (6L × 384h, ~13.8M params) for 300 steps on
//! 4 ZDP workers; pass `--model tiny --steps 30` for a smoke run or
//! `--model gpt100m` (requires `make artifacts CONFIGS=tiny,e2e,gpt100m`).
//!
//! Run: `make artifacts && cargo run --release --example train_gpt [-- flags]`

use osdp::cli::Args;
use osdp::config::Cluster;
use osdp::fabric::Topology;
use osdp::runtime::{artifacts_available, default_artifact_dir};
use osdp::train::{Corpus, ShardMode, TrainConfig, train};
use osdp::util::stats::Ema;

fn main() {
    if !artifacts_available() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let args = Args::from_env();
    let model = args.get_or("model", "e2e").to_string();
    let workers = args.usize_or("workers", 4);
    let steps = args.usize_or("steps", 300);
    let mode = match args.get_or("mode", "zdp") {
        "dp" => ShardMode::Dp,
        _ => ShardMode::Zdp,
    };
    let cluster = Cluster::rtx_titan(workers, 8.0);
    let cfg = TrainConfig {
        model: model.clone(),
        n_workers: workers,
        steps,
        mode,
        seed: args.usize_or("seed", 7) as i32,
        topology: Topology::from_cluster(&cluster),
        mem_limit: cluster.mem_limit,
        log_every: args.usize_or("log", 10),
        device_flops: cluster.flops,
        reshard_after_forward: !args.flag("no-reshard"),
    };

    println!(
        "== end-to-end: {model} on {workers} simulated devices ({mode:?}) =="
    );
    let rep = train(default_artifact_dir(), cfg).unwrap_or_else(|e| {
        eprintln!("training failed: {e:?}");
        std::process::exit(1);
    });

    // smoothed loss curve, decimated for the log
    println!("\nstep   loss     ema");
    let mut ema = Ema::new(0.1);
    let k = (rep.steps.len() / 25).max(1);
    for s in &rep.steps {
        let sm = ema.update(s.loss);
        if s.step % k == 0 || s.step == rep.steps.len() {
            println!("{:>5}  {:.4}  {:.4}", s.step, s.loss, sm);
        }
    }

    // the corpus has a known entropy floor — report convergence against it
    let mc_vocab = 8192; // e2e vocab; floor only used as a reference line
    let floor = Corpus::new(7, mc_vocab).loss_floor();
    println!(
        "\nloss {:.4} -> {:.4} (corpus entropy floor ≈ {:.3})",
        rep.first_loss(),
        rep.last_loss(),
        floor
    );
    println!(
        "wall {:.1}s | simulated {:.3}s | {} pushed per worker | peak {}",
        rep.wall_seconds,
        rep.sim_seconds,
        osdp::util::fmt_bytes(rep.bytes_sent_per_worker as f64),
        osdp::util::fmt_bytes(rep.peak_mem),
    );
    let global_batch = workers * 4; // batch_per_worker = 4 in the manifest
    println!(
        "simulated throughput: {:.1} samples/s",
        rep.sim_throughput(global_batch)
    );
    assert!(
        rep.last_loss() < rep.first_loss(),
        "loss must decrease over the run"
    );
}
