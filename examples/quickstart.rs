//! Quickstart: the full OSDP flow on one model in ~a second.
//!
//! 1. Describe a model (operator graph with memory/size factors).
//! 2. Describe the cluster (the paper's Figure 2 "Device Information").
//! 3. Run the search engine + scheduler for the optimal execution plan.
//! 4. Compare against DP / FSDP, and visualize the plan's timeline.
//!
//! Run: `cargo run --release --example quickstart`

use osdp::config::{Cluster, SearchConfig};
use osdp::cost::Profiler;
use osdp::model::{GptDims, build_gpt};
use osdp::parallel::{Ddp, Fsdp, Strategy};
use osdp::planner::Scheduler;
use osdp::sim;

fn main() {
    // -- 1. model description: a 24-layer GPT (~340M params)
    let model = build_gpt(&GptDims::uniform(
        "demo-gpt", /*vocab*/ 32000, /*seq*/ 512, /*layers*/ 24,
        /*hidden*/ 1024, /*heads*/ 16,
    ));
    println!(
        "model: {} — {:.0}M params, {} operators",
        model.name,
        model.param_count() / 1e6,
        model.n_ops()
    );

    // -- 2. device information: 8 GPUs, 8 GiB usable each
    let cluster = Cluster::rtx_titan(8, 8.0);
    let search = SearchConfig {
        max_batch: 32,
        granularities: vec![0, 4],
        checkpointing: false,
        paper_granularity: false, // plan at fine granularity
        ..Default::default()
    };

    // -- 3. OSDP: profile, search, schedule
    let profiler = Profiler::new(&model, &cluster, &search);
    println!(
        "search space: 10^{:.0} candidate plans",
        profiler.log10_plan_space()
    );
    let result = Scheduler::new(&profiler, cluster.mem_limit, search.max_batch)
        .run()
        .expect("the model should fit with sharding");
    let best = result.best_plan();
    println!("optimal plan: {}", best.describe(&profiler));
    println!(
        "  -> {:.1} samples/s on {} devices (searched {} batch sizes, {} nodes)",
        result.best_throughput(),
        cluster.n_devices,
        result.candidates.len(),
        result.total_nodes
    );

    // -- 4. against the fixed-mode baselines
    for strat in [&Ddp as &dyn Strategy, &Fsdp] {
        let e = strat.estimate(&model, &cluster, &search);
        match e.feasible {
            true => println!(
                "  {:>5}: {:>7.1} samples/s ({})",
                e.strategy, e.throughput, e.detail
            ),
            false => println!(
                "  {:>5}: {}",
                e.strategy,
                e.reason.unwrap_or_default()
            ),
        }
    }

    // -- timeline of the chosen plan (Figure-1 style, first ops only)
    let tl = sim::simulate(&model, &best.decisions, &cluster, best.batch,
                           false, true);
    println!(
        "\nsimulated iteration: {:.1} ms (compute utilization {:.0}%)",
        tl.iter_time * 1e3,
        tl.compute_utilization() * 100.0
    );
    let head: Vec<_> = tl.events.iter().take(12).cloned().collect();
    let head_tl = sim::Timeline {
        iter_time: head.iter().map(|e| e.end).fold(0.0, f64::max),
        comm_busy: 0.0,
        compute_busy: 0.0,
        events: head,
    };
    print!("{}", sim::render_gantt(&head_tl, 56));
}
