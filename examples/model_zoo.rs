//! Model-zoo explorer: Table 1 plus a per-setting OSDP plan summary — what
//! the search engine decides for every paper model at 8 GiB and 16 GiB.
//!
//! Run: `cargo run --release --example model_zoo`

use osdp::config::{Cluster, GIB, SearchConfig};
use osdp::cost::Profiler;
use osdp::figures;
use osdp::model::zoo;
use osdp::planner::Scheduler;
use osdp::util::table::Table;

fn main() {
    print!("{}", figures::table1());

    for mem in [8.0, 16.0] {
        let cluster = Cluster::rtx_titan(8, mem);
        let search = SearchConfig {
            max_batch: 32,
            granularities: vec![0, 4],
            checkpointing: false,
            paper_granularity: true,
            ..Default::default()
        };
        let mut t = Table::new(vec![
            "setting", "batch", "DP ops", "ZDP ops", "mixed", "split%",
            "peak", "samples/s",
        ]);
        for entry in zoo() {
            let profiler = Profiler::new(&entry.model, &cluster, &search);
            match Scheduler::new(&profiler, cluster.mem_limit,
                                 search.max_batch).run() {
                Err(_) => {
                    t.row(vec![entry.setting.clone(), "-".into(), "-".into(),
                               "-".into(), "-".into(), "-".into(),
                               "OOM".into(), "0".into()]);
                }
                Ok(res) => {
                    let plan = res.best_plan();
                    let (dp, zdp, mixed) = plan.mode_counts();
                    t.row(vec![
                        entry.setting.clone(),
                        plan.batch.to_string(),
                        dp.to_string(),
                        zdp.to_string(),
                        mixed.to_string(),
                        format!("{:.0}", plan.split_fraction() * 100.0),
                        format!("{:.2} GiB", plan.cost.peak_mem / GIB),
                        format!("{:.1}", res.best_throughput()),
                    ]);
                }
            }
        }
        println!("\n== OSDP plans at {mem:.0} GiB / device (8 devices) ==");
        print!("{}", t.render());
    }
}
