//! Plan explorer: how the optimal execution plan morphs as the memory
//! limit tightens — from all-DP (fastest) through mixed plans to all-ZDP
//! with splitting, and finally OOM. Makes the paper's core trade-off
//! visible in one sweep, and cross-checks the exact DFS against the greedy
//! heuristic at every point.
//!
//! Run: `cargo run --release --example plan_explorer`

use osdp::config::{Cluster, GIB, SearchConfig};
use osdp::cost::Profiler;
use osdp::model::{GptDims, build_gpt};
use osdp::planner::{dfs_search, greedy_search};
use osdp::util::table::Table;

fn main() {
    let model = build_gpt(&GptDims::uniform(
        "sweep-gpt", 32000, 512, 16, 1024, 16,
    ));
    let cluster = Cluster::rtx_titan(8, 8.0);
    let search = SearchConfig {
        max_batch: 8,
        granularities: vec![0, 4, 8],
        checkpointing: false,
        paper_granularity: true,
        ..Default::default()
    };
    let profiler = Profiler::new(&model, &cluster, &search);
    let b = 4;

    // bracket the sweep between the all-ZDP floor and the all-DP ceiling
    let dp_mem =
        profiler.evaluate(&profiler.index_of(|d| d.is_pure_dp()), b).peak_mem;
    let zdp_mem = profiler
        .evaluate(
            &profiler.index_of(|d| d.is_pure_zdp() && d.granularity == 0),
            b,
        )
        .peak_mem;
    println!(
        "model {:.0}M params | all-DP needs {:.2} GiB, all-ZDP {:.2} GiB (b={b})",
        model.param_count() / 1e6,
        dp_mem / GIB,
        zdp_mem / GIB
    );

    let mut t = Table::new(vec![
        "limit (GiB)", "feasible", "DP ops", "ZDP ops", "mixed", "split%",
        "iter (ms)", "vs greedy", "nodes",
    ]);
    for i in 0..14 {
        let frac = 0.55 + 0.05 * i as f64;
        let limit = zdp_mem * frac + 0.02 * dp_mem * i as f64;
        let dfs = dfs_search(&profiler, limit, b);
        let greedy = greedy_search(&profiler, limit, b);
        match dfs {
            None => {
                t.row(vec![format!("{:.2}", limit / GIB), "no".into(),
                           "-".into(), "-".into(), "-".into(), "-".into(),
                           "-".into(), "-".into(), "-".into()]);
            }
            Some((choice, cost, stats)) => {
                let plan = osdp::planner::ExecutionPlan::from_choice(
                    &profiler, choice, b);
                let (dp, zdp, mixed) = plan.mode_counts();
                let vs = greedy
                    .map(|(_, g)| format!("{:+.2}%",
                                          (g.time / cost.time - 1.0) * 100.0))
                    .unwrap_or_else(|| "n/a".into());
                t.row(vec![
                    format!("{:.2}", limit / GIB),
                    "yes".into(),
                    dp.to_string(),
                    zdp.to_string(),
                    mixed.to_string(),
                    format!("{:.0}", plan.split_fraction() * 100.0),
                    format!("{:.1}", cost.time * 1e3),
                    vs,
                    stats.nodes.to_string(),
                ]);
            }
        }
    }
    print!("{}", t.render());
    println!("\n'vs greedy' = how much slower the greedy heuristic's plan \
              is than the exact search at the same limit.");
}
