//! Figure 9 regeneration: OSDP vs FSDP with activation checkpointing
//! enabled (8 GiB and 16 GiB).
//!
//! The mechanism (paper §4.3): under checkpointing, ZDP operators pay an
//! *extra* parameter gather for the recomputation phase (4 rounds vs 3),
//! while DP operators pay nothing extra — so OSDP's ability to keep
//! operators in DP mode is worth more with checkpointing on (paper: up to
//! 108.3% over FSDP, average 52.9%).
//!
//! Run: `cargo bench --bench fig9_checkpointing`

use osdp::figures::{self, Quality};
use osdp::metrics::speedup;

fn main() {
    let mut with_ckpt_avg = 0.0;
    for mem in [8.0, 16.0] {
        let fig = figures::fig9(mem, Quality::Full);
        print!("{}", fig.render());
        if let Some(s) = speedup(&fig, "OSDP", "FSDP") {
            println!(
                "OSDP vs FSDP (ckpt on): max {:.1}%, avg {:.1}% over {} \
                 settings (paper: max 108.3%, avg 52.9%)\n",
                (s.max - 1.0) * 100.0,
                (s.avg - 1.0) * 100.0,
                s.n
            );
            assert!(s.avg >= 1.0, "OSDP must dominate FSDP under ckpt");
            with_ckpt_avg = s.avg;
        }
        std::fs::create_dir_all("bench_results").ok();
        std::fs::write(format!("bench_results/fig9_{mem:.0}g.csv"),
                       fig.to_csv()).ok();
    }

    // The paper's comparison point: the OSDP-over-FSDP margin grows when
    // checkpointing is on (52.9% avg with vs 22% without).
    let plain = figures::fig5(16.0, Quality::Full);
    if let Some(s) = speedup(&plain, "OSDP", "FSDP") {
        println!(
            "reference margin without ckpt at 16G: avg {:.1}% \
             (with ckpt: {:.1}%)",
            (s.avg - 1.0) * 100.0,
            (with_ckpt_avg - 1.0) * 100.0
        );
    }
}
