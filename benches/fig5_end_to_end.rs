//! Figure 5 regeneration: end-to-end throughput of every strategy on all
//! zoo settings, 8 devices, at 8 GiB and 16 GiB limits, plus the paper's
//! headline speedup statistics and shape assertions.
//!
//! Run: `cargo bench --bench fig5_end_to_end`

use osdp::bench::Bencher;
use osdp::figures::{self, Quality};
use osdp::metrics::{speedup, speedup_vs_best};

fn main() {
    let mut bencher = Bencher::new(0, 1, 1);
    for mem in [8.0, 16.0] {
        let fig = {
            let mut out = None;
            bencher.bench(&format!("fig5/{mem:.0}G"), || {
                out = Some(figures::fig5(mem, Quality::Full));
            });
            out.unwrap()
        };
        print!("{}", fig.render());

        let pct = |x: f64| (x - 1.0) * 100.0;
        if let Some(s) = speedup(&fig, "OSDP", "FSDP") {
            println!("OSDP vs FSDP          max {:>5.0}%  avg {:>5.0}%  \
                      (paper N&D: max 23%, avg 22%)", pct(s.max), pct(s.avg));
            assert!(s.avg >= 1.0, "OSDP must dominate FSDP on average");
        }
        if let Some(s) =
            speedup_vs_best(&fig, "OSDP", &["OSDP-base", "3D", "3D+OSDP"])
        {
            println!("OSDP vs best baseline max {:>5.0}%  avg {:>5.0}%  \
                      (paper: up to 174%/92%/168% per family)",
                     pct(s.max), pct(s.avg));
        }
        if let Some(s) = speedup(&fig, "3D+OSDP", "3D") {
            println!("3D+OSDP vs 3D         max {:>5.0}%  avg {:>5.0}%  \
                      (paper: max 73%, avg 31%)", pct(s.max), pct(s.avg));
            assert!(s.avg >= 0.99, "3D+OSDP must not lose to 3D on average");
        }
        if let Some(s) = speedup_vs_best(&fig, "3D+OSDP", &[]) {
            println!("3D+OSDP vs all        max {:>5.0}%  avg {:>5.0}%  \
                      (paper: max 184%, avg 38%, headline 2.84x)\n",
                     pct(s.max), pct(s.avg));
        }
        std::fs::create_dir_all("bench_results").ok();
        std::fs::write(format!("bench_results/fig5_{mem:.0}g.csv"),
                       fig.to_csv()).ok();
    }
    print!("{}", bencher.report());
    println!("wrote bench_results/fig5_*.csv");
}
