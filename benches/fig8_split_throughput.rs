//! Figure 8 regeneration: OSDP end-to-end throughput with vs without the
//! operator-splitting technique, at 8 GiB and 16 GiB.
//!
//! Paper claims: splitting "consistently improves the training throughput
//! by 3%-92%"; in W&S all operators get partitioned, in N&D ~25%, in I&C
//! ~50%. We assert splitting never hurts and produces a real win somewhere.
//!
//! Run: `cargo bench --bench fig8_split_throughput`

use osdp::figures::{self, Quality};
use osdp::metrics::speedup;

fn main() {
    for mem in [8.0, 16.0] {
        let fig = figures::fig8(mem, Quality::Full);
        print!("{}", fig.render());
        if let Some(s) = speedup(&fig, "OSDP", "OSDP-base") {
            println!(
                "splitting speedup: max {:.0}%, avg {:.0}% over {} settings \
                 (paper: 3%-92%)\n",
                (s.max - 1.0) * 100.0,
                (s.avg - 1.0) * 100.0,
                s.n
            );
            assert!(s.avg >= 1.0 - 1e-9, "splitting must not hurt on average");
            assert!(s.max > 1.02, "splitting must win somewhere");
        }
        // splitting must also *unlock* settings OSDP-base cannot fit
        let unlocked = fig
            .cells
            .iter()
            .filter(|c| c.strategy == "OSDP" && c.estimate.feasible)
            .filter(|c| {
                fig.get(&c.family, &c.setting, "OSDP-base")
                    .map(|b| !b.feasible)
                    .unwrap_or(false)
            })
            .count();
        println!("settings unlocked by splitting at {mem:.0}G: {unlocked}");
        std::fs::create_dir_all("bench_results").ok();
        std::fs::write(format!("bench_results/fig8_{mem:.0}g.csv"),
                       fig.to_csv()).ok();
    }
}
