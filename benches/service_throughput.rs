//! Plan-service throughput: what the cache, coalescer, and warm-start
//! layers buy over cold planning — cold vs warm vs cached latency, the
//! coalescing factor under concurrent identical load, and the nodes a
//! neighboring-batch warm start prunes off the 24L sweep. Writes a
//! machine-readable `BENCH_service.json` next to `BENCH_search.json`
//! (CI archives both per commit).
//!
//! Run: `cargo bench --bench service_throughput`
//!
//! The bit-identity assertions (cached == warm == cold, coalesced ==
//! leader) always run — they are deterministic. Timing thresholds gate
//! only under `OSDP_BENCH_STRICT=1` (shared runners have noisy clocks).

use osdp::config::GIB;
use osdp::cost::Profiler;
use osdp::planner::Scheduler;
use osdp::service::{Answer, Frontend, FrontendConfig, PlanQuery,
                    PlanService, QueryShape, Source, Telemetry, server};
use osdp::util::json::Json;
use std::collections::BTreeMap;
use std::time::Instant;

/// The tentpole's target instance: the 24-layer uniform GPT the fold /
/// frontier benchmarks track, served end to end.
const SETTING: &str = "gpt:5000,128,24,256,4";

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn query(mem_gib: f64, b: usize) -> PlanQuery {
    let mut q = PlanQuery::batch(SETTING, mem_gib, b);
    q.search.granularities = vec![0];
    q
}

fn plan_of(resp: &osdp::service::QueryResponse)
           -> (&osdp::planner::ExecutionPlan, u64) {
    match &resp.answer {
        Answer::Plan { plan, stats } => (plan, stats.nodes),
        Answer::Sweep { plans, best, stats } => {
            (&plans[*best], stats.nodes)
        }
    }
}

fn main() {
    let mut out: BTreeMap<String, Json> = BTreeMap::new();

    // a limit that forces real sharding decisions on the 24L stack
    let q_probe = query(8.0, 2);
    let cluster = q_probe.cluster.resolve().unwrap();
    let model = osdp::service::resolve_setting(SETTING).unwrap();
    let profiler = Profiler::new(&model, &cluster, &q_probe.search);
    let dp_peak = profiler
        .evaluate(&profiler.index_of(|d| d.is_pure_dp()), 2)
        .peak_mem;
    let mem_gib = dp_peak * 0.55 / GIB;

    println!("== plan service on the 24L uniform GPT (limit {:.3} GiB) ==",
             mem_gib);

    // ---- cold -> warm -> cached, same (limit, batch) family
    let service = PlanService::in_memory();
    let t0 = Instant::now();
    let cold = service.query(&query(mem_gib, 2)).unwrap();
    let cold_s = t0.elapsed().as_secs_f64();
    assert_eq!(cold.source, Source::Cold);
    let (cold_plan, cold_nodes) = plan_of(&cold);
    let cold_choice = cold_plan.choice.clone();
    let cold_time_bits = cold_plan.cost.time.to_bits();

    // warm starts: prime a fresh service with a neighbor entry (another
    // batch, or the same batch at a tighter limit), then measure the
    // warm-started miss against a fresh cold run of the same query.
    // Every combination must be bit-identical; the best one's node
    // reduction is the recorded figure (whether a given neighbor prunes
    // depends on whether it beats the greedy seed, so we scan a few).
    let mut best_reduction = 1.0f64;
    let mut warm_s = f64::INFINITY;
    let mut warm_rows: Vec<(String, u64, u64, &'static str)> = Vec::new();
    for (label, prime, target) in [
        ("b2->b3", query(mem_gib, 2), query(mem_gib, 3)),
        ("b1->b2", query(mem_gib, 1), query(mem_gib, 2)),
        ("tight->b3", query(mem_gib * 0.8, 3), query(mem_gib, 3)),
    ] {
        let cold_svc = PlanService::in_memory();
        let cold_resp = cold_svc.query(&target).unwrap();
        let (cold_plan, cold_n) = plan_of(&cold_resp);

        let warm_svc = PlanService::in_memory();
        warm_svc.query(&prime).unwrap();
        let t0 = Instant::now();
        let warm_resp = warm_svc.query(&target).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        let (warm_plan, warm_n) = plan_of(&warm_resp);
        assert_eq!(warm_plan.choice, cold_plan.choice,
                   "{label}: warm plan differs from cold plan");
        assert_eq!(warm_plan.cost.time.to_bits(),
                   cold_plan.cost.time.to_bits());
        assert!(warm_n <= cold_n,
                "{label}: warm explored more nodes ({warm_n} > {cold_n})");
        if warm_resp.source == Source::Warm {
            warm_s = warm_s.min(dt);
            best_reduction =
                best_reduction.max(cold_n as f64 / warm_n.max(1) as f64);
        }
        warm_rows.push((label.to_string(), cold_n, warm_n,
                        warm_resp.source.label()));
    }
    // the tighter-limit neighbor is feasible by construction, so at
    // least one scan row genuinely warm-started
    assert!(warm_s.is_finite(), "no scan row warm-started");

    // cached replay of the first query
    let t0 = Instant::now();
    let cached = service.query(&query(mem_gib, 2)).unwrap();
    let cached_s = t0.elapsed().as_secs_f64();
    assert_eq!(cached.source, Source::Cache);
    let (cached_plan, _) = plan_of(&cached);
    assert_eq!(cached_plan.choice, cold_choice);
    assert_eq!(cached_plan.cost.time.to_bits(), cold_time_bits);

    println!("cold {} ({} nodes) | warm best {} | cached {}",
             osdp::util::fmt_time(cold_s),
             cold_nodes,
             osdp::util::fmt_time(warm_s),
             osdp::util::fmt_time(cached_s));
    for (label, cn, wn, src) in &warm_rows {
        println!("  warm {label}: {cn} cold nodes -> {wn} ({src})");
    }
    out.insert("cold_s".into(), num(cold_s));
    out.insert("warm_s".into(), num(warm_s));
    out.insert("cached_s".into(), num(cached_s));
    out.insert("warm_node_reduction_best".into(), num(best_reduction));
    out.insert(
        "warm_rows".into(),
        Json::Arr(
            warm_rows
                .iter()
                .map(|(label, cn, wn, src)| {
                    let mut r = BTreeMap::new();
                    r.insert("case".into(), Json::Str(label.clone()));
                    r.insert("nodes_cold".into(), num(*cn as f64));
                    r.insert("nodes_warm".into(), num(*wn as f64));
                    r.insert("source".into(), Json::Str((*src).into()));
                    Json::Obj(r)
                })
                .collect(),
        ),
    );
    out.insert(
        "cache_hit_speedup".into(),
        num(cold_s / cached_s.max(1e-9)),
    );

    // ---- coalescing factor: 8 concurrent identical queries
    let coalesced_service = PlanService::in_memory();
    let q8 = query(mem_gib, 4);
    let barrier = std::sync::Barrier::new(8);
    let t0 = Instant::now();
    let burst: Vec<u64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let q8 = &q8;
                let svc = &coalesced_service;
                let barrier = &barrier;
                scope.spawn(move || {
                    barrier.wait();
                    let resp = svc.query(q8).unwrap();
                    plan_of(&resp).0.cost.time.to_bits()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let burst_s = t0.elapsed().as_secs_f64();
    assert!(burst.windows(2).all(|w| w[0] == w[1]),
            "coalesced answers must agree bit-for-bit");
    let stats = coalesced_service.stats();
    let factor = 8.0 / stats.planner_runs.max(1) as f64;
    println!(
        "coalescing: 8 concurrent queries -> {} planner runs \
         (factor {factor:.1}x) in {}",
        stats.planner_runs,
        osdp::util::fmt_time(burst_s),
    );
    out.insert("coalesce_queries".into(), num(8.0));
    out.insert("coalesce_runs".into(), num(stats.planner_runs as f64));
    out.insert("coalesce_factor".into(), num(factor));
    out.insert("coalesce_burst_s".into(), num(burst_s));

    // ---- warm-started sweep: nodes pruned across the whole 24L sweep
    let limit = mem_gib * GIB;
    let cold_sweep =
        Scheduler::new(&profiler, limit, 8).with_threads(1).run().unwrap();
    let warm_sweep = Scheduler::new(&profiler, limit, 8)
        .with_threads(1)
        .with_warm(cold_sweep.candidates[0].plan.choice.clone())
        .run()
        .unwrap();
    for (a, b) in cold_sweep.candidates.iter().zip(&warm_sweep.candidates) {
        assert_eq!(a.plan.choice, b.plan.choice,
                   "warm sweep diverged at b={}", a.plan.batch);
        assert_eq!(a.plan.cost.time.to_bits(),
                   b.plan.cost.time.to_bits());
    }
    println!(
        "24L sweep nodes: cold {} -> warm {} ({} candidates)",
        cold_sweep.total_nodes,
        warm_sweep.total_nodes,
        cold_sweep.candidates.len(),
    );
    out.insert("sweep_nodes_cold".into(),
               num(cold_sweep.total_nodes as f64));
    out.insert("sweep_nodes_warm".into(),
               num(warm_sweep.total_nodes as f64));

    // ---- sweep through the service populates per-batch entries
    let sweep_service = PlanService::in_memory();
    let mut sq = PlanQuery::sweep(SETTING, mem_gib, 8);
    sq.search.granularities = vec![0];
    sq.shape = QueryShape::Sweep { max_batch: 8 };
    let t0 = Instant::now();
    sweep_service.query(&sq).unwrap();
    let sweep_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let b1 = sweep_service.query(&query(mem_gib, 1)).unwrap();
    let b1_s = t0.elapsed().as_secs_f64();
    assert_eq!(b1.source, Source::Cache,
               "sweep must populate per-batch entries");
    println!(
        "service sweep {} then per-batch hit {} | service: {}",
        osdp::util::fmt_time(sweep_s),
        osdp::util::fmt_time(b1_s),
        sweep_service.stats().describe(),
    );
    out.insert("service_sweep_s".into(), num(sweep_s));
    out.insert("post_sweep_hit_s".into(), num(b1_s));

    // ---- socket front-end: concurrent cached-hit throughput over TCP.
    // One entry is primed, then every wire request is a cache hit — the
    // figure isolates transport + worker-pool + service overhead from
    // search time.
    let fe_service = std::sync::Arc::new(PlanService::in_memory());
    let telemetry = std::sync::Arc::new(Telemetry::new());
    let prime = query(mem_gib, 2);
    fe_service.query(&prime).unwrap();
    let frontend = Frontend::start(
        std::sync::Arc::clone(&fe_service),
        std::sync::Arc::clone(&telemetry),
        FrontendConfig {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            ..Default::default()
        },
    )
    .expect("bind an ephemeral loopback port");
    let addr = frontend.local_addr();
    const CONNS: usize = 8;
    const REQS: usize = 250;
    // the canonical replay line for the primed query — same key on the
    // wire as in process, by construction
    let line = server::request_line(&prime).expect("canonical line");
    let fe_barrier = std::sync::Barrier::new(CONNS);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..CONNS {
            let line = line.as_str();
            let fe_barrier = &fe_barrier;
            scope.spawn(move || {
                use std::io::{BufRead, Write};
                let stream = std::net::TcpStream::connect(addr).unwrap();
                let mut w = stream.try_clone().unwrap();
                let mut r = std::io::BufReader::new(stream);
                fe_barrier.wait();
                let mut resp = String::new();
                for _ in 0..REQS {
                    writeln!(w, "{line}").unwrap();
                    resp.clear();
                    r.read_line(&mut resp).unwrap();
                    let doc = Json::parse(resp.trim_end()).unwrap();
                    assert_eq!(doc.get("ok").as_bool(), Some(true));
                    assert_eq!(doc.get("source").as_str(), Some("cache"));
                }
            });
        }
    });
    let fe_wall_s = t0.elapsed().as_secs_f64();
    frontend.shutdown();
    frontend.join();
    let fe_total = (CONNS * REQS) as f64;
    let fe_rps = fe_total / fe_wall_s.max(1e-9);
    assert_eq!(fe_service.stats().planner_runs, 1,
               "every wire request must hit the primed cache entry");
    assert_eq!(telemetry.queries(), CONNS as u64 * REQS as u64,
               "one telemetry observation per wire query");
    let fe_p50 = telemetry.batch_latency.quantile(0.5).unwrap_or(0.0);
    let fe_p99 = telemetry.batch_latency.quantile(0.99).unwrap_or(0.0);
    println!(
        "front-end: {CONNS} conns x {REQS} cached queries in {} \
         ({fe_rps:.0} req/s; p50<={}, p99<={})",
        osdp::util::fmt_time(fe_wall_s),
        osdp::util::fmt_time(fe_p50),
        osdp::util::fmt_time(fe_p99),
    );
    let mut fe = BTreeMap::new();
    fe.insert("workers".into(), num(4.0));
    fe.insert("connections".into(), num(CONNS as f64));
    fe.insert("requests".into(), num(fe_total));
    fe.insert("wall_s".into(), num(fe_wall_s));
    fe.insert("requests_per_s".into(), num(fe_rps));
    fe.insert("p50_bound_s".into(), num(fe_p50));
    fe.insert("p99_bound_s".into(), num(fe_p99));
    out.insert("frontend".into(), Json::Obj(fe));

    // per-phase span rollups from the cold->cached service's tracer:
    // where serve time actually goes, stage by stage (empty under
    // --features no_trace — the record says so instead of lying with
    // zeros)
    let mut spans = BTreeMap::new();
    let mut span_rows: Vec<(String, u64, f64)> = Vec::new();
    for (name, h) in service.tracer().span_histograms() {
        if h.count() == 0 {
            continue;
        }
        let mut s = BTreeMap::new();
        s.insert("count".into(), num(h.count() as f64));
        s.insert("sum_s".into(), num(h.sum_s()));
        spans.insert((*name).to_string(), Json::Obj(s));
        span_rows.push(((*name).to_string(), h.count(), h.sum_s()));
    }
    if !span_rows.is_empty() {
        println!("span rollups (cold + cached serve):");
        for (name, count, sum_s) in &span_rows {
            println!("  {name}: {count} calls, {} total",
                     osdp::util::fmt_time(*sum_s));
        }
    }
    out.insert(
        "trace_enabled".into(),
        Json::Bool(osdp::service::trace::Tracer::enabled()),
    );
    out.insert("spans".into(), Json::Obj(spans));

    // schema 2: adds `schema`, `trace_enabled`, and the `spans` rollup
    // section (PR 10); consumers should skip records whose version they
    // do not know
    out.insert("schema".into(), num(2.0));

    // machine-readable record, tracked across PRs next to BENCH_search
    let path = std::env::var("OSDP_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_service.json".to_string());
    let doc = osdp::util::json::to_string(&Json::Obj(out));
    std::fs::write(&path, format!("{doc}\n")).expect("writing bench json");
    println!("\nwrote {path}");

    if std::env::var_os("OSDP_BENCH_STRICT").is_some() {
        assert!(cached_s < cold_s,
                "a cache hit ({cached_s:.6}s) must beat a cold search \
                 ({cold_s:.6}s)");
        assert!(best_reduction > 1.0,
                "some warm start must strictly prune (best reduction \
                 {best_reduction:.3}x)");
        assert!(warm_sweep.total_nodes <= cold_sweep.total_nodes,
                "warm sweep must never explore more ({} vs {} nodes)",
                warm_sweep.total_nodes, cold_sweep.total_nodes);
        assert_eq!(stats.planner_runs, 1,
                   "concurrent identical queries must coalesce");
        // deliberately conservative: cached hits over loopback are
        // tens-of-microseconds events, so even a heavily shared runner
        // clears this by orders of magnitude — the floor only catches a
        // serialized or wedged worker pool
        assert!(fe_rps > 50.0,
                "front-end served {fe_rps:.0} cached req/s — the worker \
                 pool is not actually concurrent");
    }
}
