//! Figure 7 regeneration: operator splitting's impact on per-operator
//! memory and time cost across slice granularities 0–16, for small
//! (768/1024) and large (8192/12288) hidden sizes.
//!
//! Shape assertions: memory decreases monotonically in granularity (up to
//! ~50%+ reduction, paper: "a maximum of 50% reduction"); small-hidden ops
//! pay growing latency with granularity; large-hidden ops' time is nearly
//! flat (the bandwidth term dominates their comm).
//!
//! Run: `cargo bench --bench fig7_splitting`

use osdp::figures;

fn main() {
    let (table, rows) = figures::fig7();
    println!("== Figure 7: splitting sweep (single ZDP matmul, b=8, 8 dev) ==");
    print!("{}", table.render());

    for h in [768usize, 1024, 8192, 12288] {
        let sel: Vec<_> = rows.iter().filter(|r| r.0 == h).collect();
        let mems: Vec<f64> = sel.iter().map(|r| r.2).collect();
        let times: Vec<f64> = sel.iter().map(|r| r.3).collect();
        // memory monotone decreasing
        for w in mems.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "h={h}: memory not monotone");
        }
        let mem_cut = 1.0 - mems.last().unwrap() / mems[0];
        let slowdown = times.last().unwrap() / times[0];
        println!(
            "hidden {h:>5}: peak memory -{:.0}% at g=16, time x{:.3}",
            mem_cut * 100.0,
            slowdown
        );
        if h <= 1024 {
            assert!(slowdown > 1.05,
                    "small ops must slow down with granularity");
        } else {
            assert!(slowdown < 1.05,
                    "large ops should barely slow down (bandwidth-bound)");
            assert!(mem_cut > 0.4,
                    "large ops must shed >40% peak (paper: up to 50%)");
        }
    }
    println!("shape checks passed");
}
