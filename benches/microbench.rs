//! Micro-benchmarks of the L3 hot paths (the §Perf targets in DESIGN.md):
//! fabric collectives (bytes/s through the ring), cost-model evaluation,
//! DFS node rate, simulator event rate, and PJRT execution overhead.
//!
//! Run: `cargo bench --bench microbench`

use osdp::bench::{Bencher, black_box};
use osdp::collectives::{all_gather, all_reduce, reduce_scatter};
use osdp::config::{Cluster, SearchConfig};
use osdp::cost::{Decision, Profiler};
use osdp::fabric::{self, Topology};
use osdp::model::{GptDims, build_gpt};
use osdp::sim;

fn main() {
    let mut b = Bencher::new(2, 8, 1);

    // ---- fabric collectives: real bytes through 8 threads
    for len in [1usize << 16, 1 << 20, 1 << 24] {
        let mib = (len * 4) as f64 / (1024.0 * 1024.0);
        let m = b.bench(&format!("fabric/all_reduce_8dev_{mib:.0}MiB"), || {
            // zero-latency links: measure wall transport cost, not the model
            let topo = Topology::flat(8, 0.0, 0.0);
            fabric::run(8, topo, move |ep| {
                let data = vec![1.0f32; len];
                black_box(all_reduce(ep, &data));
            })
        });
        let wall = m.per_iter();
        // each device sends (n-1)/n*2*len f32 through the mesh
        let bytes = 8.0 * 2.0 * (7.0 / 8.0) * (len * 4) as f64;
        println!("  -> {:.2} GiB/s aggregate", bytes / wall / 1e9);
    }
    for len in [1usize << 20] {
        b.bench("fabric/reduce_scatter_8dev_4MiB", || {
            let topo = Topology::flat(8, 0.0, 0.0);
            fabric::run(8, topo, move |ep| {
                black_box(reduce_scatter(ep, &vec![1.0f32; len]));
            })
        });
        b.bench("fabric/all_gather_8dev_4MiB", || {
            let topo = Topology::flat(8, 0.0, 0.0);
            fabric::run(8, topo, move |ep| {
                let shard = vec![1.0f32; len / 8];
                black_box(all_gather(ep, &shard, len));
            })
        });
    }

    // ---- cost model + planner
    let model = build_gpt(&GptDims::uniform("bench", 50257, 512, 48, 1024, 16));
    let cluster = Cluster::rtx_titan(8, 8.0);
    let search = SearchConfig {
        granularities: vec![0, 2, 4, 8],
        paper_granularity: true,
        ..Default::default()
    };
    b.bench("profiler/build_98op_tables", || {
        black_box(Profiler::new(&model, &cluster, &search))
    });
    let profiler = Profiler::new(&model, &cluster, &search);
    let choice = profiler.index_of(|d| d.is_pure_zdp());
    let mut b2 = Bencher::new(3, 10, 1000);
    b2.bench("profiler/evaluate_98op_plan", || {
        black_box(profiler.evaluate(&choice, 4))
    });

    // ---- simulator
    let decisions = vec![Decision::ZDP; model.ops.len()];
    b.bench("sim/simulate_339op_iteration", || {
        black_box(sim::simulate(&model, &decisions, &cluster, 4, false, true))
    });

    print!("{}", b.report());
    print!("{}", b2.report());
}
