//! Figure 6 regeneration: the two-server 16-device experiment (A100-like
//! nodes, 100 Gb/s inter-node link), 8 GiB and 16 GiB limits.
//!
//! Run: `cargo bench --bench fig6_two_server`

use osdp::bench::Bencher;
use osdp::figures::{self, Quality};
use osdp::metrics::speedup;

fn main() {
    let mut bencher = Bencher::new(0, 1, 1);
    for mem in [8.0, 16.0] {
        let fig = {
            let mut out = None;
            bencher.bench(&format!("fig6/{mem:.0}G"), || {
                out = Some(figures::fig6(mem, Quality::Full));
            });
            out.unwrap()
        };
        print!("{}", fig.render());
        if let Some(s) = speedup(&fig, "OSDP", "FSDP") {
            println!(
                "OSDP vs FSDP: max {:.0}%, avg {:.0}% (paper two-server: \
                 max 67%, avg 29%)\n",
                (s.max - 1.0) * 100.0,
                (s.avg - 1.0) * 100.0
            );
            assert!(s.avg >= 1.0, "OSDP must dominate FSDP on average");
        }
        std::fs::create_dir_all("bench_results").ok();
        std::fs::write(format!("bench_results/fig6_{mem:.0}g.csv"),
                       fig.to_csv()).ok();
    }
    print!("{}", bencher.report());
}
