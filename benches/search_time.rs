//! §3.2 search-cost claim: "it takes merely 9-307 seconds in our
//! experiments to complete the search process". Our branch-and-bound
//! (greedy-seeded, suffix-bounded, symmetry-folded) searches the same
//! spaces in well under a second per setting — reported here per zoo
//! model, plus planner micro-benchmarks (plans evaluated per second,
//! folded-vs-unfolded node counts, frontier-vs-folded sweep times and
//! per-class frontier point counts) and a machine-readable
//! `BENCH_search.json` so the perf trajectory is tracked across PRs (CI
//! archives it per commit).
//!
//! Run: `cargo bench --bench search_time`

use osdp::bench::Bencher;
use osdp::config::{Cluster, GIB, SearchConfig};
use osdp::cost::Profiler;
use osdp::figures::{self, Quality};
use osdp::model::{GptDims, build_gpt};
use osdp::planner::{Engine, ParallelConfig, Scheduler, dfs_search,
                    dfs_search_unfolded, parallel_search};
use osdp::util::json::Json;
use std::collections::BTreeMap;

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn main() {
    let mut out: BTreeMap<String, Json> = BTreeMap::new();

    println!("== per-setting scheduler wall clock (paper: 9-307 s) ==");
    let t = figures::search_times(8.0, Quality::Full);
    print!("{}", t.render());

    // micro: evaluation and search throughput on a 96-layer model
    let entry = osdp::model::zoo()
        .into_iter()
        .find(|e| e.setting == "96L/1536H")
        .unwrap();
    let cluster = Cluster::rtx_titan(8, 16.0);
    let search = SearchConfig {
        max_batch: 16,
        granularities: vec![0, 2, 4, 8],
        checkpointing: false,
        paper_granularity: true,
        ..Default::default()
    };
    let profiler = Profiler::new(&entry.model, &cluster, &search);
    let choice = profiler.index_of(|d| d.is_pure_zdp());

    let fold = osdp::planner::fold_report(&profiler);
    println!("\nsymmetry fold: {}", fold.describe());
    out.insert("fold_ops".into(), num(fold.ops as f64));
    out.insert("fold_classes".into(), num(fold.classes as f64));
    out.insert("fold_max_multiplicity".into(),
               num(fold.max_multiplicity as f64));
    out.insert("log10_space_unfolded".into(), num(fold.log10_unfolded));
    out.insert("log10_space_folded".into(), num(fold.log10_folded));

    let mut b = Bencher::new(3, 10, 100);
    let m = b.bench("profiler/evaluate_194op_plan", || {
        profiler.evaluate(&choice, 4)
    });
    println!("plan evaluations: {:.2} M plans/s", 1e-6 / m.per_iter());
    out.insert("evaluate_per_iter_s".into(), num(m.per_iter()));

    let mut b2 = Bencher::new(1, 5, 1);
    let m2 = b2.bench("dfs/96L_1536H_16G_b4", || {
        dfs_search(&profiler, 16.0 * GIB, 4)
    });
    println!("one search: {}", osdp::util::fmt_time(m2.per_iter()));

    let mut b3 = Bencher::new(1, 3, 1);
    let m3 = b3.bench("scheduler/96L_1536H_16G_full_sweep", || {
        Scheduler::new(&profiler, 16.0 * GIB, 16).run()
    });
    println!("full batch sweep: {}", osdp::util::fmt_time(m3.per_iter()));
    assert!(m3.per_iter() < 307.0,
            "must not exceed the paper's own upper bound");
    out.insert("sweep_wall_s".into(), num(m3.per_iter()));

    // folded vs unfolded search trees on the same GPT-XL-class menu (zoo
    // 96L/1536H, 2.9B params — the search the tentpole targets)
    println!("\n== folded vs unfolded search tree (96L/1536H, b=4) ==");
    let limit = 16.0 * GIB;
    let folded = dfs_search(&profiler, limit, 4).unwrap();
    let unfolded =
        dfs_search_unfolded(&profiler, limit, 4, 2_000_000).unwrap();
    let reduction = unfolded.2.nodes as f64 / folded.2.nodes.max(1) as f64;
    println!(
        "folded {} nodes vs unfolded {} nodes{} -> {reduction:.1}x fewer",
        folded.2.nodes,
        unfolded.2.nodes,
        if unfolded.2.complete { "" } else { " [budget expired]" },
    );
    if folded.2.complete && unfolded.2.complete {
        assert_eq!(folded.0, unfolded.0,
                   "folded planner must match the per-op engine");
        assert_eq!(folded.1.time.to_bits(), unfolded.1.time.to_bits());
    }
    out.insert("nodes_folded".into(), num(folded.2.nodes as f64));
    out.insert("nodes_unfolded".into(), num(unfolded.2.nodes as f64));
    out.insert("fold_node_reduction".into(), num(reduction));
    out.insert("unfolded_budget_expired".into(),
               Json::Bool(!unfolded.2.complete));

    // serial DFS vs the parallel branch-and-bound
    println!("\n== serial vs parallel B&B (GPT-XL-class 96L/1536H, b=4) ==");
    let mut bs = Bencher::new(1, 5, 1);
    let ms = bs.bench("search/serial_dfs", || {
        dfs_search(&profiler, limit, 4)
    });
    // folded engine explicitly: this section measures the parallel B&B
    // against the serial B&B, not the frontier engine (below)
    let cfg1 = ParallelConfig { threads: 1, engine: Engine::FoldedBb,
                                ..Default::default() };
    let cfg8 = ParallelConfig { threads: 8, engine: Engine::FoldedBb,
                                ..Default::default() };
    let mut b1 = Bencher::new(1, 5, 1);
    let m1 = b1.bench("search/parallel_1thread", || {
        parallel_search(&profiler, limit, 4, &cfg1)
    });
    let mut b8 = Bencher::new(1, 5, 1);
    let m8 = b8.bench("search/parallel_8threads", || {
        parallel_search(&profiler, limit, 4, &cfg8)
    });
    print!("{}{}{}", bs.report(), b1.report(), b8.report());

    // same answer, bit-identical, whatever the thread count (guaranteed
    // whenever the node budget doesn't expire; budget slicing differs
    // between the serial and parallel engines, so gate on completeness)
    let serial = dfs_search(&profiler, limit, 4).unwrap();
    let par = parallel_search(&profiler, limit, 4, &cfg8).unwrap();
    if serial.2.complete && par.2.complete {
        assert_eq!(serial.0, par.0, "parallel B&B must match serial DFS");
        assert_eq!(serial.1.time.to_bits(), par.1.time.to_bits());
    } else {
        println!("(budget expired: skipping bit-identity check; \
                  serial {} vs parallel {} s)",
                 serial.1.time, par.1.time);
    }

    let speedup = ms.per_iter() / m8.per_iter();
    println!(
        "serial {} | parallel(1) {} | parallel(8) {} | speedup {speedup:.2}x",
        osdp::util::fmt_time(ms.per_iter()),
        osdp::util::fmt_time(m1.per_iter()),
        osdp::util::fmt_time(m8.per_iter()),
    );
    out.insert("search_serial_s".into(), num(ms.per_iter()));
    out.insert("search_parallel1_s".into(), num(m1.per_iter()));
    out.insert("search_parallel8_s".into(), num(m8.per_iter()));
    out.insert("parallel_speedup_8t".into(), num(speedup));

    // frontier stats on the 96L menus (every class prebuilds now that the
    // incremental Minkowski-sum build has no width ceiling — recorded so
    // the build behavior is tracked across PRs too)
    let f96 = osdp::planner::frontier_report(&profiler);
    println!("\n96L frontiers: {}", f96.describe());
    out.insert("frontier_points_96l".into(), num(f96.points as f64));
    out.insert("frontier_too_wide_96l".into(), num(f96.too_wide as f64));
    out.insert("frontier_max_level_width_96l".into(),
               num(f96.max_level_width as f64));

    // frontier engine vs folded B&B on the scheduler's hot path: the
    // 24-layer uniform GPT sweep (the tentpole's target instance — one
    // frontier build amortized across every batch size of the sweep)
    println!("\n== frontier vs folded B&B sweep (24L uniform GPT, 8G) ==");
    let deep = build_gpt(&GptDims::uniform("deep", 5000, 128, 24, 256, 4));
    let cdeep = Cluster::rtx_titan(8, 8.0);
    let sdeep = SearchConfig {
        granularities: vec![0],
        paper_granularity: true,
        ..Default::default()
    };
    let pdeep = Profiler::new(&deep, &cdeep, &sdeep);
    let f24 = osdp::planner::frontier_report(&pdeep);
    println!("frontiers: {}", f24.describe());
    let mut bfo = Bencher::new(1, 5, 1);
    let mfo = bfo.bench("scheduler/24L_folded_sweep", || {
        Scheduler::new(&pdeep, 8.0 * GIB, 16)
            .with_engine(Engine::FoldedBb)
            .run()
    });
    let mut bfr = Bencher::new(1, 5, 1);
    let mfr = bfr.bench("scheduler/24L_frontier_sweep", || {
        Scheduler::new(&pdeep, 8.0 * GIB, 16).run()
    });
    print!("{}{}", bfo.report(), bfr.report());

    // same candidates, bit-identical, and never more search nodes
    let fo_sweep = Scheduler::new(&pdeep, 8.0 * GIB, 16)
        .with_engine(Engine::FoldedBb)
        .run()
        .unwrap();
    let fr_sweep = Scheduler::new(&pdeep, 8.0 * GIB, 16).run().unwrap();
    assert_eq!(fr_sweep.candidates.len(), fo_sweep.candidates.len());
    for (a, b) in fr_sweep.candidates.iter().zip(&fo_sweep.candidates) {
        assert_eq!(a.plan.choice, b.plan.choice,
                   "frontier sweep diverged at b={}", a.plan.batch);
        assert_eq!(a.plan.cost.time.to_bits(), b.plan.cost.time.to_bits());
    }
    assert!(fr_sweep.total_nodes <= fo_sweep.total_nodes,
            "frontier sweep explored more nodes");
    let sweep_speedup = mfo.per_iter() / mfr.per_iter();
    println!(
        "folded {} | frontier {} | {sweep_speedup:.2}x; sweep nodes {} -> {}",
        osdp::util::fmt_time(mfo.per_iter()),
        osdp::util::fmt_time(mfr.per_iter()),
        fo_sweep.total_nodes,
        fr_sweep.total_nodes,
    );
    out.insert("sweep24_folded_s".into(), num(mfo.per_iter()));
    out.insert("sweep24_frontier_s".into(), num(mfr.per_iter()));
    out.insert("sweep24_frontier_speedup".into(), num(sweep_speedup));
    out.insert("sweep24_nodes_folded".into(),
               num(fo_sweep.total_nodes as f64));
    out.insert("sweep24_nodes_frontier".into(),
               num(fr_sweep.total_nodes as f64));
    out.insert("frontier_classes_24l".into(), num(f24.classes as f64));
    out.insert("frontier_compositions_24l".into(),
               num(f24.compositions as f64));
    out.insert("frontier_points_24l".into(), num(f24.points as f64));
    // per-class point counts, in fold-class order
    out.insert(
        "frontier_points_per_class_24l".into(),
        Json::Arr(f24.per_class.iter().map(|s| num(s.kept as f64)).collect()),
    );
    out.insert(
        "frontier_compositions_per_class_24l".into(),
        Json::Arr(f24.per_class.iter().map(|s| num(s.raw as f64)).collect()),
    );

    // incremental-frontier ladder: deep uniform stacks with wide menus.
    // The Minkowski-sum build retired the 2^18 composition ceiling, so the
    // 96L class (and the 1000L one, whose composition count saturates any
    // one-shot enumeration) prebuilds like any other; record build widths
    // and sweep node rows so the trajectory is tracked across PRs.
    println!("\n== incremental-frontier ladder (wide menus, 96L / 1000L) ==");
    let mut sweep_rows: Vec<(usize, u64, Option<u64>, bool)> = Vec::new();
    // 96L keeps the zoo's full {0,2,4,8} menu (the shape that used to
    // overflow the one-shot ceiling); 1000L uses a 4-option {0,2} menu so
    // the ladder probes depth rather than menu width. The 1000L frontier
    // product space is ~2*2*(3m+1)^2 ≈ 36M prefixes, so its sweep gets a
    // raised node budget to keep the completeness certificate (budgets
    // never change a completed search's result).
    for &(layers, max_b, run_folded, ref grans, budget) in &[
        (96usize, 8usize, true, vec![0usize, 2, 4, 8], 2_000_000u64),
        (1000, 4, false, vec![0, 2], 64_000_000),
    ] {
        let tag = format!("sweep{layers}");
        let model = build_gpt(
            &GptDims::uniform("ladder", 5000, 128, layers, 256, 4));
        let sl = SearchConfig {
            granularities: grans.clone(),
            paper_granularity: true,
            ..Default::default()
        };
        let pl = Profiler::new(&model, &cluster, &sl);
        let mut bb = Bencher::new(1, 3, 1);
        let mb = bb.bench(&format!("frontier/{layers}L_build"), || {
            osdp::planner::frontier_report(&pl)
        });
        let fl = osdp::planner::frontier_report(&pl);
        println!("{layers}L frontiers ({} build): {}",
                 osdp::util::fmt_time(mb.per_iter()), fl.describe());
        println!("{layers}L level-wise max frontier width: {}",
                 fl.max_level_width);
        out.insert(format!("{tag}_build_s"), num(mb.per_iter()));
        out.insert(format!("{tag}_frontier_points"), num(fl.points as f64));
        out.insert(format!("{tag}_frontier_too_wide"),
                   num(fl.too_wide as f64));
        out.insert(format!("{tag}_max_level_width"),
                   num(fl.max_level_width as f64));
        out.insert(
            format!("{tag}_points_per_class"),
            Json::Arr(fl.per_class.iter()
                          .map(|s| num(s.kept as f64)).collect()),
        );
        assert_eq!(fl.too_wide, 0,
                   "{layers}L: every class must prebuild");
        for c in &fl.per_class {
            assert!(c.kept <= c.raw && c.kept <= 50_000,
                    "{layers}L: unbounded frontier class ({} points)",
                    c.kept);
        }

        // a limit between the ZDP and DP extremes so the sweep has to
        // shard without being trivially feasible
        let dp1 = pl.evaluate(&pl.index_of(|d| d.is_pure_dp()), 1).peak_mem;
        let zdp1 =
            pl.evaluate(&pl.index_of(|d| d.is_pure_zdp()), 1).peak_mem;
        let limit = zdp1 * max_b as f64 * 0.2 + dp1 * 0.55;
        let mut bfs = Bencher::new(1, 3, 1);
        let mfs = bfs.bench(&format!("scheduler/{layers}L_frontier_sweep"),
                            || {
                                Scheduler::new(&pl, limit, max_b)
                                    .with_budget(budget)
                                    .run()
                            });
        let frs = Scheduler::new(&pl, limit, max_b)
            .with_budget(budget)
            .run()
            .unwrap_or_else(|_| panic!("{layers}L sweep infeasible"));
        let complete = frs.candidates.iter().all(|c| c.stats.complete);
        println!(
            "{layers}L frontier sweep: {} | {} candidates | {} nodes{}",
            osdp::util::fmt_time(mfs.per_iter()),
            frs.candidates.len(),
            frs.total_nodes,
            if complete { "" } else { " [budget expired]" },
        );
        out.insert(format!("{tag}_frontier_sweep_s"), num(mfs.per_iter()));
        out.insert(format!("{tag}_nodes_frontier"),
                   num(frs.total_nodes as f64));

        let mut folded_nodes = None;
        if run_folded {
            let fos = Scheduler::new(&pl, limit, max_b)
                .with_engine(Engine::FoldedBb)
                .run()
                .unwrap_or_else(|_| panic!("{layers}L folded infeasible"));
            folded_nodes = Some(fos.total_nodes);
            println!("{layers}L folded sweep: {} nodes; frontier visits \
                      {:.1}% of that",
                     fos.total_nodes,
                     100.0 * frs.total_nodes as f64
                         / fos.total_nodes.max(1) as f64);
            out.insert(format!("{tag}_nodes_folded"),
                       num(fos.total_nodes as f64));
            // bit-identity whenever both engines finished within budget
            if complete && fos.candidates.iter().all(|c| c.stats.complete) {
                assert_eq!(frs.candidates.len(), fos.candidates.len());
                for (a, b) in frs.candidates.iter().zip(&fos.candidates) {
                    assert_eq!(a.plan.choice, b.plan.choice,
                               "{layers}L sweep diverged at b={}",
                               a.plan.batch);
                    assert_eq!(a.plan.cost.time.to_bits(),
                               b.plan.cost.time.to_bits());
                }
            }
        }
        sweep_rows.push((layers, frs.total_nodes, folded_nodes, complete));
    }

    // schema 2: adds the version field itself (PR 10); consumers should
    // skip records whose version they do not know
    out.insert("schema".into(), num(2.0));

    // machine-readable perf record, tracked across PRs
    let path = std::env::var("OSDP_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_search.json".to_string());
    let doc = osdp::util::json::to_string(&Json::Obj(out));
    std::fs::write(&path, format!("{doc}\n")).expect("writing bench json");
    println!("\nwrote {path}");

    if std::env::var_os("OSDP_BENCH_STRICT").is_some() {
        // hardware-aware floor: shared CI runners expose 2-4 vCPUs, where
        // an 8-thread search cannot reach the 2x an 8-core box delivers —
        // scale the expectation to the cores actually present
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let floor = match cores {
            0..=3 => 0.8, // oversubscribed: just forbid pathological slowdown
            4..=7 => 1.3,
            _ => 2.0,
        };
        assert!(speedup >= floor,
                "expected >={floor}x at 8 threads on {cores} cores, \
                 measured {speedup:.2}x");
        assert!(reduction >= 10.0,
                "expected >=10x fold reduction, measured {reduction:.1}x");
        assert!(
            mfr.per_iter() <= mfo.per_iter(),
            "frontier sweep ({}) must not be slower than the folded \
             B&B sweep ({}) on the 24L uniform GPT",
            osdp::util::fmt_time(mfr.per_iter()),
            osdp::util::fmt_time(mfo.per_iter()),
        );
        // unbounded-width ladder floors: both deep sweeps must finish
        // within the per-batch node budget (the frontier's point merges
        // are tiny next to in-place block enumeration), and the 96L
        // frontier sweep must visit no more nodes than the folded engine
        assert_eq!(f96.too_wide + f24.too_wide, 0,
                   "no class may skip the prebuild");
        for &(layers, fr_nodes, folded_nodes, complete) in &sweep_rows {
            assert!(complete,
                    "{layers}L frontier sweep must finish within budget");
            if let Some(fo_nodes) = folded_nodes {
                assert!(fr_nodes <= fo_nodes,
                        "{layers}L frontier sweep visited more nodes than \
                         the folded engine: {fr_nodes} > {fo_nodes}");
            }
        }
    }
}
